//! Streaming graph mutations: the batched edge insert/delete log
//! (DESIGN.md §14).
//!
//! A [`DeltaBatch`] is an ordered list of [`MutationOp`]s that commits
//! **transactionally**: readers observe either the pre-batch graph or the
//! post-batch graph, never an intermediate state. [`apply`] materializes
//! the post-batch [`CsrGraph`] in one pass and reports the *touched*
//! endpoint set that seeds incremental recompute
//! (`alg::incremental`), and [`rebuild_partitions`] refreshes the live
//! [`PartitionedGraph`] — reusing the same rebuild-and-remap machinery the
//! dynamic-α controller uses (placement-preserving `build_placed`, which
//! re-derives ghost tables and lets transpose CSRs rebuild lazily) — with
//! a commit-time reassignment tier that absorbs mutation-induced load
//! skew.
//!
//! ## Text format (the `--mutations` replay file)
//!
//! ```text
//! # comment / blank lines ignored
//! add <src> <dst> [<weight>]   # weight required iff the graph is weighted
//! del <src> <dst>              # removes ALL parallel copies of (src, dst)
//! commit                       # batch boundary; trailing ops form a final batch
//! ```
//!
//! ## Batch semantics
//!
//! Within one batch, deletes are resolved against the **pre-batch** graph
//! first, then inserts are appended in op order — so an edge both deleted
//! and inserted in the same batch survives with the inserted weight, and
//! the rebuilt CSR's intra-row edge order is deterministic (surviving old
//! edges in old CSR order, then inserts in batch order). Inserting an
//! endpoint `>=` the current vertex count grows the graph; deleting a
//! never-present edge is a counted no-op (`delete_misses`), not an error,
//! and crucially does **not** count as an *effective* delete — only
//! effective deletes force the monotone programs off the warm-start path
//! (DESIGN.md §14.3).

use std::collections::HashSet;

use super::csr::{CsrGraph, EdgeList};
use super::IngestError;
use crate::partition::{assign, PartitionedGraph, Strategy};

/// Edge-share deviation (realized vs target, max over partitions) above
/// which a mutation commit re-runs assignment from scratch instead of
/// extending the previous one — the α controller's commit-time tier.
pub const DEFAULT_SKEW_THRESHOLD: f64 = 0.10;

/// One entry in the mutation log.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MutationOp {
    /// Append an edge. `weight` must be `Some` iff the graph is weighted.
    Insert { src: u32, dst: u32, weight: Option<f32> },
    /// Remove every parallel copy of `(src, dst)` present pre-batch.
    Delete { src: u32, dst: u32 },
}

/// An ordered group of mutations that commits atomically.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeltaBatch {
    pub ops: Vec<MutationOp>,
}

/// Typed errors raised by mutation parsing and application.
#[derive(Debug, Clone, PartialEq)]
pub enum DeltaError {
    /// A malformed line in a mutation file (1-based line number).
    Parse { line: u64, msg: String },
    /// Insert carried a weight but the graph is unweighted.
    UnexpectedWeight { src: u32, dst: u32 },
    /// Insert on a weighted graph omitted the weight.
    MissingWeight { src: u32, dst: u32 },
    /// An endpoint id does not fit the platform's `usize` (+1 for the
    /// vertex count) — same checked-narrowing contract as `graph/io.rs`.
    VertexOverflow { id: u32 },
    /// Rebuilding the CSR failed (the batch is rejected, graph unchanged).
    Rebuild(IngestError),
}

impl std::fmt::Display for DeltaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeltaError::Parse { line, msg } => write!(f, "mutation file line {line}: {msg}"),
            DeltaError::UnexpectedWeight { src, dst } => {
                write!(f, "insert {src} -> {dst} carries a weight but the graph is unweighted")
            }
            DeltaError::MissingWeight { src, dst } => {
                write!(f, "insert {src} -> {dst} omits the weight the weighted graph requires")
            }
            DeltaError::VertexOverflow { id } => {
                write!(f, "vertex id {id} does not fit this platform's usize")
            }
            DeltaError::Rebuild(e) => write!(f, "delta rebuild failed: {e}"),
        }
    }
}

impl std::error::Error for DeltaError {}

impl From<IngestError> for DeltaError {
    fn from(e: IngestError) -> Self {
        DeltaError::Rebuild(e)
    }
}

impl DeltaBatch {
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Parse a whole mutation file into its committed batches (module
    /// docs give the grammar). Trailing ops without a final `commit` form
    /// a last batch; empty batches (e.g. `commit commit`) are dropped.
    pub fn parse_file(text: &str) -> Result<Vec<DeltaBatch>, DeltaError> {
        let mut batches = Vec::new();
        let mut cur = DeltaBatch::default();
        for (i, raw) in text.lines().enumerate() {
            let line = i as u64 + 1;
            let s = raw.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let mut it = s.split_whitespace();
            let verb = it.next().unwrap();
            match verb {
                "commit" => {
                    if it.next().is_some() {
                        return Err(DeltaError::Parse {
                            line,
                            msg: "commit takes no operands".into(),
                        });
                    }
                    if !cur.is_empty() {
                        batches.push(std::mem::take(&mut cur));
                    }
                }
                "add" | "del" => {
                    let src = parse_id(it.next(), line, "src")?;
                    let dst = parse_id(it.next(), line, "dst")?;
                    let op = if verb == "add" {
                        let weight = match it.next() {
                            None => None,
                            Some(w) => Some(w.parse::<f32>().map_err(|_| DeltaError::Parse {
                                line,
                                msg: format!("bad weight {w:?}"),
                            })?),
                        };
                        MutationOp::Insert { src, dst, weight }
                    } else {
                        MutationOp::Delete { src, dst }
                    };
                    if it.next().is_some() {
                        return Err(DeltaError::Parse {
                            line,
                            msg: format!("trailing tokens after {verb}"),
                        });
                    }
                    cur.ops.push(op);
                }
                other => {
                    return Err(DeltaError::Parse {
                        line,
                        msg: format!("unknown verb {other:?} (expected add/del/commit)"),
                    });
                }
            }
        }
        if !cur.is_empty() {
            batches.push(cur);
        }
        Ok(batches)
    }

    /// Seeded random batch over an existing graph: `n_ops` operations,
    /// each a delete of a uniformly sampled existing edge with probability
    /// `delete_frac`, else an insert between uniform endpoints (weighted
    /// iff the graph is). Fully determined by `seed` — the differential
    /// fuzzer's mutation axis uses it directly; the CI `mutate-smoke`
    /// replay drives its Python mirror (`tools/cross_check_mutations.py
    /// emit`) to author the replay files.
    pub fn seeded(g: &CsrGraph, n_ops: usize, delete_frac: f64, seed: u64) -> DeltaBatch {
        let mut rng = crate::util::rng::Rng::new(seed);
        let n = g.vertex_count.max(1) as u64;
        let edges: Vec<(u32, u32)> = g.iter_edges().collect();
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            if !edges.is_empty() && rng.next_f64() < delete_frac {
                let (src, dst) = edges[rng.below(edges.len() as u64) as usize];
                ops.push(MutationOp::Delete { src, dst });
            } else {
                let src = rng.below(n) as u32;
                let dst = rng.below(n) as u32;
                let weight = g
                    .weights
                    .is_some()
                    // match `generator::with_random_weights`: positive
                    // small integers, exactly representable in f32
                    .then(|| (rng.below(64) + 1) as f32);
                ops.push(MutationOp::Insert { src, dst, weight });
            }
        }
        DeltaBatch { ops }
    }

    /// Render in the `parse_file` grammar (without the trailing `commit`).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        for op in &self.ops {
            match op {
                MutationOp::Insert { src, dst, weight: Some(w) } => {
                    s.push_str(&format!("add {src} {dst} {w}\n"));
                }
                MutationOp::Insert { src, dst, weight: None } => {
                    s.push_str(&format!("add {src} {dst}\n"));
                }
                MutationOp::Delete { src, dst } => {
                    s.push_str(&format!("del {src} {dst}\n"));
                }
            }
        }
        s
    }
}

fn parse_id(tok: Option<&str>, line: u64, what: &str) -> Result<u32, DeltaError> {
    let t = tok.ok_or_else(|| DeltaError::Parse { line, msg: format!("missing {what}") })?;
    t.parse::<u32>()
        .map_err(|_| DeltaError::Parse { line, msg: format!("bad {what} {t:?}") })
}

/// The committed result of applying one batch.
#[derive(Debug, Clone)]
pub struct AppliedDelta {
    /// The post-batch graph.
    pub graph: CsrGraph,
    /// Sorted, deduplicated endpoints of every applied insert and every
    /// *effective* delete — the seed set for affected-frontier recompute.
    pub touched: Vec<u32>,
    /// Edges appended.
    pub inserted: u64,
    /// Edge copies actually removed.
    pub deleted: u64,
    /// `del` ops that matched nothing pre-batch (counted no-ops).
    pub delete_misses: u64,
    /// Vertices the batch grew the graph by.
    pub new_vertices: usize,
    /// At least one edge copy was really removed — monotone warm starts
    /// are invalid and incremental recompute must fall back to a full run.
    pub effective_deletes: bool,
}

/// Apply one batch transactionally (module docs give the semantics); on
/// any error the input graph is untouched.
pub fn apply(g: &CsrGraph, batch: &DeltaBatch) -> Result<AppliedDelta, DeltaError> {
    let weighted = g.weights.is_some();
    let mut nv = g.vertex_count;
    let mut delete_pairs: HashSet<(u32, u32)> = HashSet::new();
    let mut inserts: Vec<(u32, u32, f32)> = Vec::new();
    for op in &batch.ops {
        match *op {
            MutationOp::Insert { src, dst, weight } => {
                match (weighted, weight) {
                    (true, None) => return Err(DeltaError::MissingWeight { src, dst }),
                    (false, Some(_)) => return Err(DeltaError::UnexpectedWeight { src, dst }),
                    _ => {}
                }
                for id in [src, dst] {
                    let wanted = usize::try_from(id)
                        .ok()
                        .and_then(|x| x.checked_add(1))
                        .ok_or(DeltaError::VertexOverflow { id })?;
                    nv = nv.max(wanted);
                }
                inserts.push((src, dst, weight.unwrap_or(0.0)));
            }
            MutationOp::Delete { src, dst } => {
                delete_pairs.insert((src, dst));
            }
        }
    }

    let mut el = EdgeList::new(nv);
    el.edges.reserve(g.edge_count() + inserts.len());
    if weighted {
        el.weights = Some(Vec::with_capacity(g.edge_count() + inserts.len()));
    }
    let mut deleted = 0u64;
    let mut deleted_pairs_hit: HashSet<(u32, u32)> = HashSet::new();
    for v in 0..g.vertex_count as u32 {
        let nbrs = g.neighbors(v);
        let ws = if weighted { g.edge_weights(v) } else { &[] };
        for (i, &t) in nbrs.iter().enumerate() {
            if delete_pairs.contains(&(v, t)) {
                deleted += 1;
                deleted_pairs_hit.insert((v, t));
                continue;
            }
            el.edges.push((v, t));
            if let Some(w) = el.weights.as_mut() {
                w.push(ws[i]);
            }
        }
    }
    let inserted = inserts.len() as u64;
    for &(src, dst, w) in &inserts {
        el.edges.push((src, dst));
        if let Some(ws) = el.weights.as_mut() {
            ws.push(w);
        }
    }

    let graph = CsrGraph::try_from_edge_list(&el)?;

    let mut touched: Vec<u32> = inserts
        .iter()
        .flat_map(|&(s, d, _)| [s, d])
        .chain(deleted_pairs_hit.iter().flat_map(|&(s, d)| [s, d]))
        .collect();
    touched.sort_unstable();
    touched.dedup();

    Ok(AppliedDelta {
        graph,
        touched,
        inserted,
        deleted,
        delete_misses: (delete_pairs.len() - deleted_pairs_hit.len()) as u64,
        new_vertices: nv - g.vertex_count,
        effective_deletes: deleted > 0,
    })
}

/// How a mutation commit rebuilt the live partitioning.
#[derive(Debug)]
pub struct RebuildOutcome {
    pub pg: PartitionedGraph,
    /// `true` when edge-share skew exceeded the threshold and assignment
    /// was re-run from scratch instead of extended.
    pub reassigned: bool,
    /// Max |realized − target| edge share after the rebuild actually used.
    pub skew: f64,
}

/// Rebuild the partitioning for the post-batch graph.
///
/// Fast path: extend the previous global→partition assignment (new
/// vertices go to the partition whose member count is furthest below its
/// target share, lowest id on ties — deterministic) and re-run the
/// placement-preserving [`PartitionedGraph::build_placed`], which refreshes
/// local CSRs, ghost tables, and (lazily) transpose CSRs exactly like the
/// α controller's migration path. If the realized edge shares then deviate
/// from the targets by more than `skew_threshold`, the commit absorbs the
/// skew by re-running [`assign`] from scratch with the original strategy,
/// shares, and seed.
pub fn rebuild_partitions(
    g_new: &CsrGraph,
    prev: &PartitionedGraph,
    strategy: Strategy,
    shares: &[f64],
    seed: u64,
    skew_threshold: f64,
) -> RebuildOutcome {
    let nparts = prev.parts.len();
    debug_assert_eq!(shares.len(), nparts);
    let mut asg = prev.part_of.clone();
    if g_new.vertex_count > asg.len() {
        let total: f64 = shares.iter().sum();
        let mut members = vec![0usize; nparts];
        for &p in &asg {
            members[p as usize] += 1;
        }
        for _ in asg.len()..g_new.vertex_count {
            // deficit = target fraction − realized fraction; argmax wins
            let n_now = asg.len().max(1) as f64;
            let pick = (0..nparts)
                .max_by(|&a, &b| {
                    let da = shares[a] / total - members[a] as f64 / n_now;
                    let db = shares[b] / total - members[b] as f64 / n_now;
                    da.partial_cmp(&db).unwrap().then(b.cmp(&a))
                })
                .unwrap();
            asg.push(pick as u8);
            members[pick] += 1;
        }
    }
    let pg = PartitionedGraph::build_placed(g_new, &asg, nparts, prev.placement);
    let skew = share_skew(&pg.edge_shares(), shares);
    if nparts > 1 && skew > skew_threshold {
        let fresh = assign(g_new, strategy, shares, seed);
        let pg = PartitionedGraph::build_placed(g_new, &fresh, nparts, prev.placement);
        let skew = share_skew(&pg.edge_shares(), shares);
        return RebuildOutcome { pg, reassigned: true, skew };
    }
    RebuildOutcome { pg, reassigned: false, skew }
}

fn share_skew(realized: &[f64], target: &[f64]) -> f64 {
    let total: f64 = target.iter().sum();
    realized
        .iter()
        .zip(target)
        .map(|(r, t)| (r - t / total.max(f64::MIN_POSITIVE)).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{Placement, Strategy};

    fn diamond() -> CsrGraph {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn parse_batches_and_roundtrip() {
        let text = "# header\nadd 1 2\ndel 0 3\ncommit\n\nadd 5 6\n";
        let batches = DeltaBatch::parse_file(text).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(
            batches[0].ops,
            vec![
                MutationOp::Insert { src: 1, dst: 2, weight: None },
                MutationOp::Delete { src: 0, dst: 3 },
            ]
        );
        let re = DeltaBatch::parse_file(&batches[0].to_text()).unwrap();
        assert_eq!(re[0], batches[0]);
    }

    #[test]
    fn parse_rejects_garbage() {
        for (bad, want) in [
            ("frobnicate 1 2", "unknown verb"),
            ("add 1", "missing dst"),
            ("add 1 x", "bad dst"),
            ("add 1 2 zz", "bad weight"),
            ("del 1 2 3", "trailing tokens"),
            ("commit now", "commit takes no operands"),
        ] {
            match DeltaBatch::parse_file(bad) {
                Err(DeltaError::Parse { line: 1, msg }) => {
                    assert!(msg.contains(want), "{bad:?}: {msg}")
                }
                other => panic!("{bad:?}: expected parse error, got {other:?}"),
            }
        }
    }

    #[test]
    fn apply_insert_grows_and_touches() {
        let g = diamond();
        let batch = DeltaBatch {
            ops: vec![MutationOp::Insert { src: 3, dst: 5, weight: None }],
        };
        let a = apply(&g, &batch).unwrap();
        assert_eq!(a.graph.vertex_count, 6);
        assert_eq!(a.graph.edge_count(), 5);
        assert_eq!(a.new_vertices, 2);
        assert_eq!(a.touched, vec![3, 5]);
        assert!(!a.effective_deletes);
        // pre-existing rows untouched
        assert_eq!(a.graph.neighbors(0), &[1, 2]);
        assert_eq!(a.graph.neighbors(3), &[5]);
    }

    #[test]
    fn apply_delete_removes_all_copies_and_counts_misses() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(0, 1); // parallel copy
        el.push(1, 2);
        let g = CsrGraph::from_edge_list(&el);
        let batch = DeltaBatch {
            ops: vec![
                MutationOp::Delete { src: 0, dst: 1 },
                MutationOp::Delete { src: 2, dst: 0 }, // never present
            ],
        };
        let a = apply(&g, &batch).unwrap();
        assert_eq!(a.deleted, 2);
        assert_eq!(a.delete_misses, 1);
        assert!(a.effective_deletes);
        assert_eq!(a.graph.edge_count(), 1);
        // misses do not pollute the touched seed set
        assert_eq!(a.touched, vec![0, 1]);
    }

    #[test]
    fn delete_then_insert_same_edge_survives() {
        let g = diamond();
        let batch = DeltaBatch {
            ops: vec![
                MutationOp::Delete { src: 0, dst: 1 },
                MutationOp::Insert { src: 0, dst: 1, weight: None },
            ],
        };
        let a = apply(&g, &batch).unwrap();
        assert_eq!(a.graph.edge_count(), 4);
        assert_eq!(a.graph.neighbors(0), &[2, 1]); // survivors first, insert appended
        assert!(a.effective_deletes);
    }

    #[test]
    fn weight_arity_is_typed() {
        let g = diamond(); // unweighted
        let b = DeltaBatch { ops: vec![MutationOp::Insert { src: 0, dst: 1, weight: Some(2.0) }] };
        assert_eq!(apply(&g, &b), Err(DeltaError::UnexpectedWeight { src: 0, dst: 1 }));

        let mut el = EdgeList::new(2);
        el.push(0, 1);
        el.weights = Some(vec![1.0]);
        let wg = CsrGraph::from_edge_list(&el);
        let b = DeltaBatch { ops: vec![MutationOp::Insert { src: 1, dst: 0, weight: None }] };
        assert_eq!(apply(&wg, &b), Err(DeltaError::MissingWeight { src: 1, dst: 0 }));
    }

    #[test]
    fn weighted_apply_keeps_weights_parallel() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(1, 2);
        el.weights = Some(vec![4.0, 7.0]);
        let g = CsrGraph::from_edge_list(&el);
        let b = DeltaBatch {
            ops: vec![
                MutationOp::Delete { src: 0, dst: 1 },
                MutationOp::Insert { src: 2, dst: 0, weight: Some(9.0) },
            ],
        };
        let a = apply(&g, &b).unwrap();
        assert_eq!(a.graph.edge_weights(1), &[7.0]);
        assert_eq!(a.graph.edge_weights(2), &[9.0]);
    }

    #[test]
    fn seeded_batches_are_deterministic() {
        let g = diamond();
        let a = DeltaBatch::seeded(&g, 16, 0.3, 42);
        let b = DeltaBatch::seeded(&g, 16, 0.3, 42);
        assert_eq!(a, b);
        assert_eq!(a.ops.len(), 16);
        let c = DeltaBatch::seeded(&g, 16, 0.3, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn rebuild_extends_assignment_then_reassigns_on_skew() {
        let g = diamond();
        let pg = PartitionedGraph::partition_placed(
            &g,
            Strategy::Rand,
            &[0.5, 0.5],
            7,
            Placement::DegreeDesc,
        );
        // no growth, generous threshold: assignment must be extended as-is
        let out = rebuild_partitions(&g, &pg, Strategy::Rand, &[0.5, 0.5], 7, 1e9);
        assert!(!out.reassigned);
        assert_eq!(out.pg.part_of, pg.part_of);
        assert_eq!(out.pg.placement, pg.placement);

        // grow the graph and force the skew tier with a zero threshold
        let batch = DeltaBatch {
            ops: (0..8).map(|i| MutationOp::Insert { src: 4 + i, dst: 0, weight: None }).collect(),
        };
        let a = apply(&g, &batch).unwrap();
        let out = rebuild_partitions(&a.graph, &pg, Strategy::Rand, &[0.5, 0.5], 7, -1.0);
        assert!(out.reassigned);
        assert_eq!(out.pg.global_vertex_count, 12);
        // every vertex got a partition and the graph rebuilt consistently
        assert_eq!(out.pg.part_of.len(), 12);
    }

    #[test]
    fn rebuild_assigns_new_vertices_toward_deficit() {
        let g = diamond();
        let pg = PartitionedGraph::partition_placed(
            &g,
            Strategy::Rand,
            &[0.75, 0.25],
            3,
            Placement::AssignmentOrder,
        );
        let batch =
            DeltaBatch { ops: vec![MutationOp::Insert { src: 4, dst: 5, weight: None }] };
        let a = apply(&g, &batch).unwrap();
        let out = rebuild_partitions(&a.graph, &pg, Strategy::Rand, &[0.75, 0.25], 3, 1e9);
        // previous vertices keep their partitions on the fast path
        assert_eq!(&out.pg.part_of[..4], &pg.part_of[..]);
        assert_eq!(out.pg.part_of.len(), 6);
    }
}
