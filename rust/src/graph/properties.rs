//! Graph topology statistics — degree distribution characterization.
//!
//! The paper's whole thesis rests on degree heterogeneity ("scale-free"
//! graphs, §1/§2). These helpers quantify it: degree histograms, top-k
//! edge share (how much of |E| the high-degree vertices own), and a Gini
//! coefficient of the degree distribution. They feed the report tables and
//! guard the generator tests (RMAT must be skewed, UNIFORM must not be).

use super::csr::CsrGraph;

/// Summary of a graph's degree distribution.
#[derive(Debug, Clone)]
pub struct DegreeStats {
    pub vertex_count: usize,
    pub edge_count: usize,
    pub max_degree: u64,
    pub mean_degree: f64,
    /// Fraction of edges owned by the top 1% highest-degree vertices.
    pub top1pct_edge_share: f64,
    /// Gini coefficient of out-degrees in [0,1]; ~0 uniform, →1 skewed.
    pub gini: f64,
    /// Number of vertices with zero out-degree.
    pub zero_degree: usize,
}

pub fn degree_stats(g: &CsrGraph) -> DegreeStats {
    let mut degs = g.out_degrees();
    let v = g.vertex_count.max(1);
    let e = g.edge_count();
    let max_degree = degs.iter().copied().max().unwrap_or(0);
    let zero_degree = degs.iter().filter(|&&d| d == 0).count();
    degs.sort_unstable();
    let top_k = (v / 100).max(1);
    let top_edges: u64 = degs[v - top_k.min(v)..].iter().sum();
    // Gini via the sorted formula: G = (2 Σ i·x_i) / (n Σ x_i) - (n+1)/n
    let total: u64 = degs.iter().sum();
    let gini = if total == 0 {
        0.0
    } else {
        let weighted: f64 = degs
            .iter()
            .enumerate()
            .map(|(i, &d)| (i as f64 + 1.0) * d as f64)
            .sum();
        (2.0 * weighted) / (v as f64 * total as f64) - (v as f64 + 1.0) / v as f64
    };
    DegreeStats {
        vertex_count: g.vertex_count,
        edge_count: e,
        max_degree,
        mean_degree: e as f64 / v as f64,
        top1pct_edge_share: if e == 0 { 0.0 } else { top_edges as f64 / e as f64 },
        gini,
        zero_degree,
    }
}

/// Log-binned degree histogram: `(lower_bound, count)` per bin. Used by the
/// report to show the power-law shape.
pub fn degree_histogram_log2(g: &CsrGraph) -> Vec<(u64, usize)> {
    let mut bins: Vec<usize> = Vec::new();
    for v in 0..g.vertex_count as u32 {
        let d = g.out_degree(v);
        let bin = if d == 0 { 0 } else { 64 - d.leading_zeros() as usize };
        if bins.len() <= bin {
            bins.resize(bin + 1, 0);
        }
        bins[bin] += 1;
    }
    bins.into_iter()
        .enumerate()
        .map(|(b, c)| (if b == 0 { 0 } else { 1u64 << (b - 1) }, c))
        .collect()
}

/// Number of vertices needed (taken highest-degree-first) to cover `frac`
/// of all edges. On scale-free graphs this is tiny — the mechanism behind
/// the HIGH strategy's two-orders-of-magnitude |V_cpu| reduction (Fig. 13).
pub fn vertices_covering_edge_fraction(g: &CsrGraph, frac: f64) -> usize {
    let mut degs = g.out_degrees();
    degs.sort_unstable_by(|a, b| b.cmp(a));
    let target = (g.edge_count() as f64 * frac).ceil() as u64;
    let mut acc = 0u64;
    for (i, d) in degs.iter().enumerate() {
        acc += d;
        if acc >= target {
            return i + 1;
        }
    }
    g.vertex_count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, uniform, RmatParams};
    use crate::graph::CsrGraph;

    #[test]
    fn rmat_more_skewed_than_uniform() {
        let gr = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(12, 1)));
        let gu = CsrGraph::from_edge_list(&uniform(12, 16, 1));
        let sr = degree_stats(&gr);
        let su = degree_stats(&gu);
        assert!(sr.gini > su.gini + 0.2, "gini rmat={} uni={}", sr.gini, su.gini);
        assert!(sr.top1pct_edge_share > 2.0 * su.top1pct_edge_share);
    }

    #[test]
    fn mean_degree_matches() {
        let g = CsrGraph::from_edge_list(&uniform(10, 8, 2));
        let s = degree_stats(&g);
        assert!((s.mean_degree - 8.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_sums_to_v() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 3)));
        let h = degree_histogram_log2(&g);
        let total: usize = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, g.vertex_count);
    }

    #[test]
    fn coverage_is_small_on_scale_free() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(12, 5)));
        let n50 = vertices_covering_edge_fraction(&g, 0.5);
        // On RMAT, half the edges belong to a small fraction of vertices.
        assert!(
            n50 < g.vertex_count / 5,
            "n50={n50} of {}",
            g.vertex_count
        );
    }

    #[test]
    fn coverage_full_fraction() {
        let g = CsrGraph::from_edge_list(&uniform(8, 4, 1));
        let n = vertices_covering_edge_fraction(&g, 1.0);
        assert!(n <= g.vertex_count);
    }
}
