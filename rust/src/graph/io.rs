//! Graph serialization.
//!
//! Two formats:
//! - a text edge-list format (`src dst [weight]` per line, `#` comments,
//!   `p <V> <E>` header optional but validated when present) —
//!   interchange with the outside world, parsed streamingly so convert
//!   jobs never hold the file in RAM;
//! - the binary CSR container (`.tcsr`): v2 (DESIGN.md §12) is the
//!   written format — sectioned, explicitly little-endian, checksummed,
//!   and genuinely memory-mappable via [`super::store::GraphStore`]; the
//!   legacy v1 snapshot is still read (and written by
//!   [`write_csr_v1`] for migration tests). The paper treats graph
//!   loading as an amortized pre-processing cost (§5, "Time
//!   Measurements"); v2 + mmap makes the amortized cost a page fault.
//!
//! All ingest entry points here return errors, never panic, on malformed
//! data: out-of-range vertex ids, header/tally mismatches, and mixed
//! weightedness surface as [`IngestError`] values in the error chain
//! (ISSUE 7 satellite bugfixes).

use super::csr::{CsrGraph, EdgeList};
use super::store::{self, read_vec_le, write_slice_le, GraphStore};
use super::IngestError;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Write a text edge list.
pub fn write_edge_list(el: &EdgeList, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# totem edge list")?;
    writeln!(w, "p {} {}", el.vertex_count, el.edges.len())?;
    match &el.weights {
        Some(ws) => {
            for (&(s, d), &wt) in el.edges.iter().zip(ws) {
                writeln!(w, "{s} {d} {wt}")?;
            }
        }
        None => {
            for &(s, d) in &el.edges {
                writeln!(w, "{s} {d}")?;
            }
        }
    }
    Ok(())
}

/// Write a CSR graph as a text edge list, streaming — no intermediate
/// `EdgeList`. Weights print via Rust's shortest-round-trip float
/// formatting, so text→CSR→text→CSR is bit-stable.
pub fn write_edge_list_from_csr(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# totem edge list")?;
    writeln!(w, "p {} {}", g.vertex_count, g.edge_count())?;
    for s in 0..g.vertex_count as u32 {
        match &g.weights {
            Some(_) => {
                for (&d, &wt) in g.neighbors(s).iter().zip(g.edge_weights(s)) {
                    writeln!(w, "{s} {d} {wt}")?;
                }
            }
            None => {
                for &d in g.neighbors(s) {
                    writeln!(w, "{s} {d}")?;
                }
            }
        }
    }
    Ok(())
}

/// What a streaming edge-list pass learned about the file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElSummary {
    pub vertex_count: usize,
    pub edge_count: u64,
    pub weighted: bool,
    /// The `p` header's edge count, when the file had one.
    pub declared_edges: Option<u64>,
}

/// Stream a text edge list through `sink`, one call per edge, without
/// materializing it. Enforces the format contract as typed errors:
/// - a `p <V> [E]` header must precede all edges and appear at most once;
/// - with a header, every endpoint is range-checked against `V` as it is
///   read ([`IngestError::EdgeOutOfRange`] names the edge and line);
/// - the first edge fixes weightedness; a change is
///   [`IngestError::MixedWeights`];
/// - at EOF a declared `E` must equal the actual tally —
///   [`IngestError::EdgeCountMismatch`] otherwise (a truncated file used
///   to load silently).
pub fn stream_edge_list(
    path: &Path,
    sink: &mut dyn FnMut(u32, u32, Option<f32>) -> Result<()>,
) -> Result<ElSummary> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let r = BufReader::new(f);
    let mut declared_v: Option<usize> = None;
    let mut declared_e: Option<u64> = None;
    let mut max_id = 0u32;
    let mut count = 0u64;
    let mut weighted: Option<bool> = None;
    for (ln, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut parts = t.split_whitespace();
        let first = parts.next().unwrap();
        if first == "p" {
            if declared_v.is_some() {
                bail!("line {}: duplicate p header", ln + 1);
            }
            if count > 0 {
                bail!("line {}: p header after edges", ln + 1);
            }
            let v: usize = parts
                .next()
                .context("p line: missing V")?
                .parse()
                .context("p line: bad V")?;
            declared_e = match parts.next() {
                Some(tok) => Some(tok.parse::<u64>().context("p line: bad E")?),
                None => None,
            };
            declared_v = Some(v);
            continue;
        }
        let s: u32 = first.parse().with_context(|| format!("line {}: bad src", ln + 1))?;
        let d: u32 = parts
            .next()
            .with_context(|| format!("line {}: missing dst", ln + 1))?
            .parse()
            .with_context(|| format!("line {}: bad dst", ln + 1))?;
        let wt: Option<f32> = match parts.next() {
            Some(tok) => Some(
                tok.parse().with_context(|| format!("line {}: bad weight", ln + 1))?,
            ),
            None => None,
        };
        match weighted {
            None => weighted = Some(wt.is_some()),
            Some(expect) => {
                if expect != wt.is_some() {
                    return Err(anyhow::Error::from(IngestError::MixedWeights {
                        line: ln as u64 + 1,
                    })
                    .context(format!("{path:?}")));
                }
            }
        }
        if let Some(v) = declared_v {
            if s as usize >= v || d as usize >= v {
                return Err(anyhow::Error::from(IngestError::EdgeOutOfRange {
                    index: count,
                    src: s,
                    dst: d,
                    vertex_count: v,
                })
                .context(format!("{path:?} line {}", ln + 1)));
            }
        }
        max_id = max_id.max(s).max(d);
        sink(s, d, wt)?;
        count += 1;
    }
    let vertex_count = match declared_v {
        Some(v) => v,
        None if count == 0 => 0,
        // checked: `max_id as usize + 1` would wrap on a 32-bit host when
        // the file names vertex u32::MAX (ISSUE 9 satellite bugfix)
        None => usize::try_from(max_id)
            .ok()
            .and_then(|m| m.checked_add(1))
            .ok_or_else(|| {
                anyhow::Error::from(IngestError::CountOverflow {
                    what: "vertex",
                    count: max_id as u64 + 1,
                })
                .context(format!("{path:?}"))
            })?,
    };
    if let Some(e) = declared_e {
        if e != count {
            return Err(anyhow::Error::from(IngestError::EdgeCountMismatch {
                declared: e,
                actual: count,
            })
            .context(format!("{path:?}")));
        }
    }
    Ok(ElSummary {
        vertex_count,
        edge_count: count,
        weighted: weighted.unwrap_or(false),
        declared_edges: declared_e,
    })
}

/// One no-op streaming pass: header + tallies only. `totem convert` runs
/// this first to size the spill builder, then streams again to build.
pub fn scan_edge_list(path: &Path) -> Result<ElSummary> {
    stream_edge_list(path, &mut |_, _, _| Ok(()))
}

/// Read a text edge list into memory. Vertices are sized from the `p`
/// header if present, else `max id + 1`; all `stream_edge_list` checks
/// apply (notably: a declared edge count that disagrees with the actual
/// tally is an error, where it used to be silently ignored).
pub fn read_edge_list(path: &Path) -> Result<EdgeList> {
    let mut el = EdgeList::new(0);
    let mut weights: Vec<f32> = Vec::new();
    let summary = stream_edge_list(path, &mut |s, d, wt| {
        el.edges.push((s, d));
        if let Some(w) = wt {
            weights.push(w);
        }
        Ok(())
    })?;
    el.vertex_count = summary.vertex_count;
    if summary.weighted {
        el.weights = Some(weights);
    }
    Ok(el)
}

fn write_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Write the binary CSR snapshot. Since ISSUE 7 this emits the v2
/// container ([`store::write_csr_v2`]); readers still accept v1.
pub fn write_csr(g: &CsrGraph, path: &Path) -> Result<()> {
    store::write_csr_v2(g, path)?;
    Ok(())
}

/// Write the legacy v1 snapshot (header + raw LE arrays, no table, no
/// checksums). Kept for the v1→v2 migration path and its tests.
pub fn write_csr_v1(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(store::MAGIC)?;
    write_u32(&mut w, store::VERSION_V1)?;
    write_u32(&mut w, if g.weights.is_some() { 1 } else { 0 })?;
    write_u64(&mut w, g.vertex_count as u64)?;
    write_u64(&mut w, g.edge_count() as u64)?;
    write_slice_le(&mut w, g.row_offsets.as_slice())?;
    write_slice_le(&mut w, g.col_indices.as_slice())?;
    if let Some(ws) = &g.weights {
        write_slice_le(&mut w, ws.as_slice())?;
    }
    Ok(())
}

/// Header bytes of the v1 binary CSR format: magic + version + weighted
/// flag + |V| + |E|.
const CSR_V1_HEADER_BYTES: u64 = 8 + 4 + 4 + 8 + 8;

/// Read a binary CSR snapshot, any version — v1 through the legacy
/// reader below, v2 through [`GraphStore`] (buffered or mapped per
/// platform default, checksums verified).
pub fn read_csr(path: &Path) -> Result<CsrGraph> {
    Ok(GraphStore::open(path)?.into_graph())
}

/// Read the legacy v1 snapshot.
///
/// Defensive against corrupt or truncated files: the declared |V|/|E| are
/// checked against the actual file length *before* any allocation (a
/// corrupted count would otherwise attempt an absurd allocation and
/// abort), truncation mid-array is a typed error, and out-of-range vertex
/// ids are rejected by the structural validation — never a panic.
pub fn read_csr_v1(path: &Path) -> Result<CsrGraph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{path:?}: truncated header"))?;
    if &magic != store::MAGIC {
        bail!("{path:?}: not a totem CSR file");
    }
    let ver = read_u32(&mut r).with_context(|| format!("{path:?}: truncated header"))?;
    if ver != store::VERSION_V1 {
        bail!("{path:?}: unsupported version {ver}");
    }
    let weighted =
        read_u32(&mut r).with_context(|| format!("{path:?}: truncated header"))? == 1;
    let v64 = read_u64(&mut r).with_context(|| format!("{path:?}: truncated header"))?;
    let e64 = read_u64(&mut r).with_context(|| format!("{path:?}: truncated header"))?;

    // Size sanity before any allocation, in checked u64 arithmetic.
    let body = v64
        .checked_add(1)
        .and_then(|rows| rows.checked_mul(8))
        .and_then(|b| b.checked_add(e64.checked_mul(4)?))
        .and_then(|b| b.checked_add(if weighted { e64.checked_mul(4)? } else { 0 }))
        .ok_or_else(|| {
            anyhow::anyhow!("{path:?}: corrupt header (|V|={v64}, |E|={e64} overflow)")
        })?;
    let expected = CSR_V1_HEADER_BYTES
        .checked_add(body)
        .ok_or_else(|| anyhow::anyhow!("{path:?}: corrupt header"))?;
    if file_len < expected {
        bail!(
            "{path:?}: truncated CSR file — header declares |V|={v64}, |E|={e64} \
             ({expected} bytes) but the file holds {file_len}"
        );
    }
    if file_len > expected {
        bail!("{path:?}: {} trailing bytes after CSR payload", file_len - expected);
    }

    // Typed narrowing: the bare `v64 as usize` / `e64 as usize` this
    // replaced silently truncated >4G counts on 32-bit hosts, making the
    // reader allocate tiny arrays for a huge payload (ISSUE 9 satellite
    // bugfix). The +1 for the offsets row is checked for the same reason.
    let overflow = |what: &'static str, count: u64| {
        anyhow::Error::from(IngestError::CountOverflow { what, count })
            .context(format!("{path:?}"))
    };
    let v = usize::try_from(v64).map_err(|_| overflow("vertex", v64))?;
    let e = usize::try_from(e64).map_err(|_| overflow("edge", e64))?;
    let rows = v
        .checked_add(1)
        .ok_or_else(|| overflow("row-offset", v64.saturating_add(1)))?;
    let row_offsets: Vec<u64> = read_vec_le(&mut r, rows)
        .with_context(|| format!("{path:?}: truncated row offsets"))?;
    let col_indices: Vec<u32> =
        read_vec_le(&mut r, e).with_context(|| format!("{path:?}: truncated column indices"))?;
    let weights = if weighted {
        Some(
            read_vec_le::<f32>(&mut r, e)
                .with_context(|| format!("{path:?}: truncated weights"))?,
        )
    } else {
        None
    };
    let g = CsrGraph {
        vertex_count: v,
        row_offsets: row_offsets.into(),
        col_indices: col_indices.into(),
        weights: weights.map(Into::into),
    };
    g.validate().map_err(|e| anyhow::anyhow!("{path:?}: corrupt CSR: {e}"))?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, with_random_weights, RmatParams};
    use crate::graph::store::{peek_version, MAGIC, VERSION_V2};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("totem_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn edge_list_text_roundtrip() {
        let mut el = rmat(&RmatParams::paper(6, 1));
        with_random_weights(&mut el, 16, 2);
        let p = tmp("a.el");
        write_edge_list(&el, &p).unwrap();
        let back = read_edge_list(&p).unwrap();
        assert_eq!(back.vertex_count, el.vertex_count);
        assert_eq!(back.edges, el.edges);
        assert_eq!(back.weights, el.weights);
    }

    #[test]
    fn edge_list_without_header_sizes_from_ids() {
        let p = tmp("b.el");
        std::fs::write(&p, "# c\n0 5\n5 2\n").unwrap();
        let el = read_edge_list(&p).unwrap();
        assert_eq!(el.vertex_count, 6);
        assert_eq!(el.edges, vec![(0, 5), (5, 2)]);
    }

    #[test]
    fn edge_list_validates_declared_edge_count() {
        // Pre-ISSUE-7 the declared E was parsed and discarded, so a
        // truncated file loaded silently. Now it is a typed error.
        let p = tmp("short.el");
        std::fs::write(&p, "p 4 3\n0 1\n1 2\n").unwrap();
        let err = read_edge_list(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("edge count mismatch"), "{msg}");
        assert!(msg.contains("declares 3") && msg.contains("holds 2"), "{msg}");
        // padded files (more edges than declared) are equally an error
        std::fs::write(&p, "p 4 1\n0 1\n1 2\n").unwrap();
        assert!(read_edge_list(&p).is_err());
        // a header without E keeps the old lenient behavior
        std::fs::write(&p, "p 4\n0 1\n1 2\n").unwrap();
        let el = read_edge_list(&p).unwrap();
        assert_eq!(el.edges.len(), 2);
    }

    #[test]
    fn edge_list_header_position_rules() {
        let p = tmp("hdr.el");
        std::fs::write(&p, "0 1\np 4 1\n").unwrap();
        let msg = format!("{:#}", read_edge_list(&p).unwrap_err());
        assert!(msg.contains("p header after edges"), "{msg}");
        std::fs::write(&p, "p 4 1\np 4 1\n0 1\n").unwrap();
        let msg = format!("{:#}", read_edge_list(&p).unwrap_err());
        assert!(msg.contains("duplicate p header"), "{msg}");
    }

    #[test]
    fn scan_matches_read() {
        let mut el = rmat(&RmatParams::paper(6, 4));
        with_random_weights(&mut el, 16, 5);
        let p = tmp("scan.el");
        write_edge_list(&el, &p).unwrap();
        let s = scan_edge_list(&p).unwrap();
        assert_eq!(s.vertex_count, el.vertex_count);
        assert_eq!(s.edge_count, el.edges.len() as u64);
        assert!(s.weighted);
        assert_eq!(s.declared_edges, Some(el.edges.len() as u64));
    }

    #[test]
    fn csr_text_streaming_writer_roundtrips() {
        let mut el = rmat(&RmatParams::paper(6, 11));
        with_random_weights(&mut el, 16, 12);
        let g = CsrGraph::from_edge_list(&el);
        let p = tmp("fromcsr.el");
        write_edge_list_from_csr(&g, &p).unwrap();
        let g2 = CsrGraph::from_edge_list(&read_edge_list(&p).unwrap());
        assert_eq!(g2.row_offsets, g.row_offsets);
        assert_eq!(g2.col_indices, g.col_indices);
        assert_eq!(g2.weights, g.weights);
    }

    #[test]
    fn csr_binary_roundtrip() {
        let mut el = rmat(&RmatParams::paper(8, 3));
        with_random_weights(&mut el, 64, 4);
        let g = CsrGraph::from_edge_list(&el);
        let p = tmp("c.tcsr");
        write_csr(&g, &p).unwrap();
        assert_eq!(peek_version(&p).unwrap(), VERSION_V2, "write_csr emits v2 now");
        let back = read_csr(&p).unwrap();
        assert_eq!(back.vertex_count, g.vertex_count);
        assert_eq!(back.row_offsets, g.row_offsets);
        assert_eq!(back.col_indices, g.col_indices);
        assert_eq!(back.weights, g.weights);
    }

    #[test]
    fn csr_v1_legacy_roundtrip_still_reads() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 5)));
        let p = tmp("legacy.tcsr");
        write_csr_v1(&g, &p).unwrap();
        assert_eq!(peek_version(&p).unwrap(), 1);
        // both the explicit v1 reader and the version-dispatching one
        let back = read_csr_v1(&p).unwrap();
        assert_eq!(back.col_indices, g.col_indices);
        let back2 = read_csr(&p).unwrap();
        assert_eq!(back2.col_indices, g.col_indices);
        assert_eq!(back2.row_offsets, g.row_offsets);
    }

    #[test]
    fn csr_rejects_corruption() {
        let p = tmp("d.tcsr");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(read_csr(&p).is_err());
    }

    #[test]
    fn csr_rejects_truncated_payload() {
        // write a valid snapshot, then chop bytes off the tail: every
        // prefix must fail with a "truncated" error, not a panic.
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(6, 8)));
        let p = tmp("trunc.tcsr");
        write_csr(&g, &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        for keep in [full.len() - 1, full.len() / 2, 40, 20, 9, 0] {
            let q = tmp("trunc_cut.tcsr");
            std::fs::write(&q, &full[..keep]).unwrap();
            let err = read_csr(&q).expect_err(&format!("accepted {keep}-byte prefix"));
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("not a totem"),
                "keep={keep}: {msg}"
            );
        }
    }

    #[test]
    fn csr_rejects_absurd_header_counts_before_allocating() {
        // a v1 header declaring |V| = u64::MAX: must fail on the size
        // check — never attempt the corresponding allocation.
        let p = tmp("absurd.tcsr");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // v1
        bytes.extend_from_slice(&0u32.to_le_bytes()); // unweighted
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // |V|
        bytes.extend_from_slice(&8u64.to_le_bytes()); // |E|
        std::fs::write(&p, &bytes).unwrap();
        let err = read_csr(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("corrupt header"), "{msg}");

        // large-but-not-overflowing count with a tiny file: truncation
        let mut bytes2 = Vec::new();
        bytes2.extend_from_slice(MAGIC);
        bytes2.extend_from_slice(&1u32.to_le_bytes());
        bytes2.extend_from_slice(&0u32.to_le_bytes());
        bytes2.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes2.extend_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&p, &bytes2).unwrap();
        let msg = format!("{:#}", read_csr(&p).unwrap_err());
        assert!(msg.contains("truncated"), "{msg}");
    }

    #[test]
    fn csr_rejects_trailing_garbage() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(6, 9)));
        let p = tmp("trailing.tcsr");
        write_csr(&g, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&p, &bytes).unwrap();
        let msg = format!("{:#}", read_csr(&p).unwrap_err());
        assert!(msg.contains("trailing"), "{msg}");
    }

    #[test]
    fn csr_rejects_out_of_range_column_index() {
        // structurally valid v1 sizes, but a column index >= |V|: caught
        // by validation with an error, not a panic downstream.
        let p = tmp("oor.tcsr");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // v1
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // |V| = 2
        bytes.extend_from_slice(&1u64.to_le_bytes()); // |E| = 1
        for off in [0u64, 1, 1] {
            bytes.extend_from_slice(&off.to_le_bytes());
        }
        bytes.extend_from_slice(&99u32.to_le_bytes()); // dst 99 out of range
        std::fs::write(&p, &bytes).unwrap();
        let msg = format!("{:#}", read_csr(&p).unwrap_err());
        assert!(msg.contains("corrupt CSR"), "{msg}");
    }

    #[test]
    fn edge_list_rejects_out_of_range_vertex_ids() {
        let p = tmp("range.el");
        std::fs::write(&p, "p 4 2\n0 1\n2 9\n").unwrap();
        let msg = format!("{:#}", read_edge_list(&p).unwrap_err());
        assert!(msg.contains("out of declared range"), "{msg}");
        // the typed error names the edge and the line
        assert!(msg.contains("2 -> 9"), "{msg}");
        assert!(msg.contains("line 3"), "{msg}");
    }

    #[test]
    fn edge_list_rejects_malformed_lines() {
        let p = tmp("malformed.el");
        std::fs::write(&p, "0\n").unwrap(); // missing dst
        assert!(read_edge_list(&p).is_err());
        std::fs::write(&p, "0 x\n").unwrap(); // non-numeric dst
        assert!(read_edge_list(&p).is_err());
        std::fs::write(&p, "0 1 notaweight\n").unwrap();
        assert!(read_edge_list(&p).is_err());
    }

    #[test]
    fn mixed_weights_rejected() {
        let p = tmp("e.el");
        std::fs::write(&p, "0 1 2.0\n1 0\n").unwrap();
        let msg = format!("{:#}", read_edge_list(&p).unwrap_err());
        assert!(msg.contains("mixed weighted/unweighted"), "{msg}");
        // and the other direction
        std::fs::write(&p, "0 1\n1 0 2.0\n").unwrap();
        assert!(read_edge_list(&p).is_err());
    }
}
