//! Graph serialization.
//!
//! Two formats:
//! - a text edge-list format (`src dst [weight]` per line, `#` comments,
//!   `p <V> <E>` header optional) — interchange with the outside world;
//! - a fast little-endian binary CSR snapshot (`.tcsr`) so benchmark
//!   workloads are generated once and memory-mapped-style loaded after —
//!   the paper treats graph loading as an amortized pre-processing cost
//!   (§5, "Time Measurements").

use super::csr::{CsrGraph, EdgeList};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"TOTEMCSR";
const VERSION: u32 = 1;

/// Write a text edge list.
pub fn write_edge_list(el: &EdgeList, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# totem edge list")?;
    writeln!(w, "p {} {}", el.vertex_count, el.edges.len())?;
    match &el.weights {
        Some(ws) => {
            for (&(s, d), &wt) in el.edges.iter().zip(ws) {
                writeln!(w, "{s} {d} {wt}")?;
            }
        }
        None => {
            for &(s, d) in &el.edges {
                writeln!(w, "{s} {d}")?;
            }
        }
    }
    Ok(())
}

/// Read a text edge list. Vertices are sized from the `p` header if
/// present, else `max id + 1`.
pub fn read_edge_list(path: &Path) -> Result<EdgeList> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let r = BufReader::new(f);
    let mut el = EdgeList::new(0);
    let mut weights: Vec<f32> = Vec::new();
    let mut saw_weights = false;
    let mut max_id = 0u32;
    for (ln, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut parts = t.split_whitespace();
        let first = parts.next().unwrap();
        if first == "p" {
            let v: usize = parts
                .next()
                .context("p line: missing V")?
                .parse()
                .context("p line: bad V")?;
            el.vertex_count = v;
            continue;
        }
        let s: u32 = first.parse().with_context(|| format!("line {}: bad src", ln + 1))?;
        let d: u32 = parts
            .next()
            .with_context(|| format!("line {}: missing dst", ln + 1))?
            .parse()
            .with_context(|| format!("line {}: bad dst", ln + 1))?;
        if let Some(wtok) = parts.next() {
            let wt: f32 = wtok.parse().with_context(|| format!("line {}: bad weight", ln + 1))?;
            weights.push(wt);
            saw_weights = true;
        } else if saw_weights {
            bail!("line {}: mixed weighted/unweighted edges", ln + 1);
        }
        max_id = max_id.max(s).max(d);
        el.edges.push((s, d));
    }
    if el.vertex_count == 0 && !el.edges.is_empty() {
        el.vertex_count = max_id as usize + 1;
    }
    if el.vertex_count <= max_id as usize && !el.edges.is_empty() {
        bail!("vertex id {max_id} out of declared range {}", el.vertex_count);
    }
    if saw_weights {
        if weights.len() != el.edges.len() {
            bail!("mixed weighted/unweighted edges");
        }
        el.weights = Some(weights);
    }
    Ok(el)
}

fn write_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_slice<T: Copy>(w: &mut impl Write, xs: &[T]) -> Result<()> {
    // Safe for the POD types we use (u32/u64/f32), little-endian hosts.
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
    };
    w.write_all(bytes)?;
    Ok(())
}

fn read_vec<T: Copy + Default>(r: &mut impl Read, n: usize) -> Result<Vec<T>> {
    let mut v = vec![T::default(); n];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, n * std::mem::size_of::<T>())
    };
    r.read_exact(bytes)?;
    Ok(v)
}

/// Write the binary CSR snapshot.
pub fn write_csr(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u32(&mut w, if g.weights.is_some() { 1 } else { 0 })?;
    write_u64(&mut w, g.vertex_count as u64)?;
    write_u64(&mut w, g.edge_count() as u64)?;
    write_slice(&mut w, &g.row_offsets)?;
    write_slice(&mut w, &g.col_indices)?;
    if let Some(ws) = &g.weights {
        write_slice(&mut w, ws)?;
    }
    Ok(())
}

/// Header bytes of the binary CSR format: magic + version + weighted flag
/// + |V| + |E|.
const CSR_HEADER_BYTES: u64 = 8 + 4 + 4 + 8 + 8;

/// Read the binary CSR snapshot.
///
/// Defensive against corrupt or truncated files: the declared |V|/|E| are
/// checked against the actual file length *before* any allocation (a
/// corrupted count would otherwise attempt an absurd allocation and
/// abort), truncation mid-array is a typed error, and out-of-range vertex
/// ids are rejected by the structural validation — never a panic.
pub fn read_csr(path: &Path) -> Result<CsrGraph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{path:?}: truncated header"))?;
    if &magic != MAGIC {
        bail!("{path:?}: not a totem CSR file");
    }
    let ver = read_u32(&mut r).with_context(|| format!("{path:?}: truncated header"))?;
    if ver != VERSION {
        bail!("{path:?}: unsupported version {ver}");
    }
    let weighted =
        read_u32(&mut r).with_context(|| format!("{path:?}: truncated header"))? == 1;
    let v64 = read_u64(&mut r).with_context(|| format!("{path:?}: truncated header"))?;
    let e64 = read_u64(&mut r).with_context(|| format!("{path:?}: truncated header"))?;

    // Size sanity before any allocation, in checked u64 arithmetic.
    let body = v64
        .checked_add(1)
        .and_then(|rows| rows.checked_mul(8))
        .and_then(|b| b.checked_add(e64.checked_mul(4)?))
        .and_then(|b| b.checked_add(if weighted { e64.checked_mul(4)? } else { 0 }))
        .ok_or_else(|| {
            anyhow::anyhow!("{path:?}: corrupt header (|V|={v64}, |E|={e64} overflow)")
        })?;
    let expected = CSR_HEADER_BYTES
        .checked_add(body)
        .ok_or_else(|| anyhow::anyhow!("{path:?}: corrupt header"))?;
    if file_len < expected {
        bail!(
            "{path:?}: truncated CSR file — header declares |V|={v64}, |E|={e64} \
             ({expected} bytes) but the file holds {file_len}"
        );
    }
    if file_len > expected {
        bail!("{path:?}: {} trailing bytes after CSR payload", file_len - expected);
    }

    let v = v64 as usize;
    let e = e64 as usize;
    let row_offsets: Vec<u64> = read_vec(&mut r, v + 1)
        .with_context(|| format!("{path:?}: truncated row offsets"))?;
    let col_indices: Vec<u32> =
        read_vec(&mut r, e).with_context(|| format!("{path:?}: truncated column indices"))?;
    let weights = if weighted {
        Some(
            read_vec::<f32>(&mut r, e)
                .with_context(|| format!("{path:?}: truncated weights"))?,
        )
    } else {
        None
    };
    let g = CsrGraph { vertex_count: v, row_offsets, col_indices, weights };
    g.validate().map_err(|e| anyhow::anyhow!("{path:?}: corrupt CSR: {e}"))?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, with_random_weights, RmatParams};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("totem_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn edge_list_text_roundtrip() {
        let mut el = rmat(&RmatParams::paper(6, 1));
        with_random_weights(&mut el, 16, 2);
        let p = tmp("a.el");
        write_edge_list(&el, &p).unwrap();
        let back = read_edge_list(&p).unwrap();
        assert_eq!(back.vertex_count, el.vertex_count);
        assert_eq!(back.edges, el.edges);
        assert_eq!(back.weights, el.weights);
    }

    #[test]
    fn edge_list_without_header_sizes_from_ids() {
        let p = tmp("b.el");
        std::fs::write(&p, "# c\n0 5\n5 2\n").unwrap();
        let el = read_edge_list(&p).unwrap();
        assert_eq!(el.vertex_count, 6);
        assert_eq!(el.edges, vec![(0, 5), (5, 2)]);
    }

    #[test]
    fn csr_binary_roundtrip() {
        let mut el = rmat(&RmatParams::paper(8, 3));
        with_random_weights(&mut el, 64, 4);
        let g = CsrGraph::from_edge_list(&el);
        let p = tmp("c.tcsr");
        write_csr(&g, &p).unwrap();
        let back = read_csr(&p).unwrap();
        assert_eq!(back.vertex_count, g.vertex_count);
        assert_eq!(back.row_offsets, g.row_offsets);
        assert_eq!(back.col_indices, g.col_indices);
        assert_eq!(back.weights, g.weights);
    }

    #[test]
    fn csr_rejects_corruption() {
        let p = tmp("d.tcsr");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(read_csr(&p).is_err());
    }

    #[test]
    fn csr_rejects_truncated_payload() {
        // write a valid snapshot, then chop bytes off the tail: every
        // prefix must fail with a "truncated" error, not a panic.
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(6, 8)));
        let p = tmp("trunc.tcsr");
        write_csr(&g, &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        for keep in [full.len() - 1, full.len() / 2, 40, 20, 9, 0] {
            let q = tmp("trunc_cut.tcsr");
            std::fs::write(&q, &full[..keep]).unwrap();
            let err = read_csr(&q).expect_err(&format!("accepted {keep}-byte prefix"));
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("not a totem"),
                "keep={keep}: {msg}"
            );
        }
    }

    #[test]
    fn csr_rejects_absurd_header_counts_before_allocating() {
        // header declares |V| = u64::MAX: must fail on the size check —
        // never attempt the corresponding allocation.
        let p = tmp("absurd.tcsr");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes()); // unweighted
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // |V|
        bytes.extend_from_slice(&8u64.to_le_bytes()); // |E|
        std::fs::write(&p, &bytes).unwrap();
        let err = read_csr(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("corrupt header"), "{msg}");

        // large-but-not-overflowing count with a tiny file: truncation
        let mut bytes2 = Vec::new();
        bytes2.extend_from_slice(MAGIC);
        bytes2.extend_from_slice(&VERSION.to_le_bytes());
        bytes2.extend_from_slice(&0u32.to_le_bytes());
        bytes2.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes2.extend_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&p, &bytes2).unwrap();
        let msg = format!("{:#}", read_csr(&p).unwrap_err());
        assert!(msg.contains("truncated"), "{msg}");
    }

    #[test]
    fn csr_rejects_trailing_garbage() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(6, 9)));
        let p = tmp("trailing.tcsr");
        write_csr(&g, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&p, &bytes).unwrap();
        let msg = format!("{:#}", read_csr(&p).unwrap_err());
        assert!(msg.contains("trailing"), "{msg}");
    }

    #[test]
    fn csr_rejects_out_of_range_column_index() {
        // structurally valid sizes, but a column index >= |V|: caught by
        // validation with an error, not a panic downstream.
        let p = tmp("oor.tcsr");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // |V| = 2
        bytes.extend_from_slice(&1u64.to_le_bytes()); // |E| = 1
        for off in [0u64, 1, 1] {
            bytes.extend_from_slice(&off.to_le_bytes());
        }
        bytes.extend_from_slice(&99u32.to_le_bytes()); // dst 99 out of range
        std::fs::write(&p, &bytes).unwrap();
        let msg = format!("{:#}", read_csr(&p).unwrap_err());
        assert!(msg.contains("corrupt CSR"), "{msg}");
    }

    #[test]
    fn edge_list_rejects_out_of_range_vertex_ids() {
        let p = tmp("range.el");
        std::fs::write(&p, "p 4 2\n0 1\n2 9\n").unwrap();
        let msg = format!("{:#}", read_edge_list(&p).unwrap_err());
        assert!(msg.contains("out of declared range"), "{msg}");
    }

    #[test]
    fn edge_list_rejects_malformed_lines() {
        let p = tmp("malformed.el");
        std::fs::write(&p, "0\n").unwrap(); // missing dst
        assert!(read_edge_list(&p).is_err());
        std::fs::write(&p, "0 x\n").unwrap(); // non-numeric dst
        assert!(read_edge_list(&p).is_err());
        std::fs::write(&p, "0 1 notaweight\n").unwrap();
        assert!(read_edge_list(&p).is_err());
    }

    #[test]
    fn mixed_weights_rejected() {
        let p = tmp("e.el");
        std::fs::write(&p, "0 1 2.0\n1 0\n").unwrap();
        assert!(read_edge_list(&p).is_err());
    }
}
