//! The `.tcsr` v2 on-disk CSR container and its out-of-core loaders
//! (DESIGN.md §12).
//!
//! v2 replaces the v1 "header + raw arrays" snapshot with a durable
//! contract: a section-offset table, explicit little-endian encoding on
//! every field (with a zero-copy fast path on little-endian hosts), and
//! FNV-1a 64 checksums over the header and every section. The layout is
//! fixed and canonical — given (|V|, |E|, weighted) there is exactly one
//! valid byte stream — so two writers that agree on the graph agree on
//! the file, byte for byte.
//!
//! ```text
//! offset  size  field
//!      0     8  magic "TOTEMCSR"
//!      8     4  version (u32 LE) = 2
//!     12     4  flags   (u32 LE; bit 0 = weighted, others must be 0)
//!     16     8  |V|     (u64 LE)
//!     24     8  |E|     (u64 LE)
//!     32     4  n_sections (u32 LE; 2 unweighted, 3 weighted)
//!     36     4  reserved (u32 LE) = 0
//!     40  32·n  section table, canonical order row/col/weights:
//!               { kind u32, elem_bytes u32, file_offset u64,
//!                 elem_count u64, fnv1a64 u64 }
//! 40+32n     8  header checksum: FNV-1a 64 over bytes [0, 40+32n)
//! 48+32n     …  sections, each 8-byte aligned, zero padding between;
//!               the file ends exactly at the last section's end
//! ```
//!
//! Loading goes through [`GraphStore`]: on little-endian Unix the file is
//! memory-mapped and the CSR arrays are zero-copy [`Segment::Mapped`]
//! views into the mapping (pages fault in on demand, so |E| ≫ RAM graphs
//! stream through partition build); everywhere else — or on request — a
//! buffered reader materializes owned vectors with per-element endian
//! conversion. Both paths verify checksums (skippable for huge mapped
//! graphs where eager verification would fault every page) and both end
//! in `CsrGraph::validate`.

use super::csr::CsrGraph;
use super::IngestError;
use crate::util::mmap::{mmap_supported, Mmap};
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
#[cfg_attr(not(all(unix, target_endian = "little")), allow(unused_imports))]
use std::sync::Arc;

pub const MAGIC: &[u8; 8] = b"TOTEMCSR";
pub const VERSION_V1: u32 = 1;
pub const VERSION_V2: u32 = 2;

const FLAG_WEIGHTED: u32 = 1;
pub const SEC_ROW: u32 = 1;
pub const SEC_COL: u32 = 2;
pub const SEC_WEIGHTS: u32 = 3;

/// magic + version + flags + |V| + |E| + n_sections + reserved.
const FIXED_HEADER_BYTES: u64 = 8 + 4 + 4 + 8 + 8 + 4 + 4;
const TABLE_ENTRY_BYTES: u64 = 32;

// ---------------------------------------------------------------------------
// POD element types and explicit little-endian slice IO
// ---------------------------------------------------------------------------

mod sealed {
    pub trait Sealed {}
    impl Sealed for u32 {}
    impl Sealed for u64 {}
    impl Sealed for f32 {}
}

/// The three element types the container stores. Sealed: the on-disk
/// contract enumerates exactly these encodings (DESIGN.md §12.1).
pub trait Pod: Copy + Default + PartialEq + std::fmt::Debug + sealed::Sealed + 'static {
    const ELEM_BYTES: usize;
    fn put_le(self, out: &mut [u8]);
    fn get_le(b: &[u8]) -> Self;
}

impl Pod for u32 {
    const ELEM_BYTES: usize = 4;
    fn put_le(self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_le_bytes());
    }
    fn get_le(b: &[u8]) -> u32 {
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl Pod for u64 {
    const ELEM_BYTES: usize = 8;
    fn put_le(self, out: &mut [u8]) {
        out[..8].copy_from_slice(&self.to_le_bytes());
    }
    fn get_le(b: &[u8]) -> u64 {
        u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

impl Pod for f32 {
    const ELEM_BYTES: usize = 4;
    fn put_le(self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.to_bits().to_le_bytes());
    }
    fn get_le(b: &[u8]) -> f32 {
        f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// Write a POD slice in little-endian on-disk order. On LE hosts the
/// in-memory representation *is* the on-disk representation, so the write
/// is a single zero-copy `write_all`; big-endian hosts convert through a
/// bounded scratch buffer — this is what makes the format portable
/// (pre-v2 `write_slice` silently emitted host order).
pub fn write_slice_le<T: Pod>(w: &mut impl Write, xs: &[T]) -> Result<()> {
    #[cfg(target_endian = "little")]
    {
        let bytes = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
        };
        w.write_all(bytes)?;
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut buf = [0u8; 8192];
        let per = buf.len() / T::ELEM_BYTES;
        for chunk in xs.chunks(per) {
            for (i, &x) in chunk.iter().enumerate() {
                x.put_le(&mut buf[i * T::ELEM_BYTES..]);
            }
            w.write_all(&buf[..chunk.len() * T::ELEM_BYTES])?;
        }
    }
    Ok(())
}

/// Read `n` little-endian POD elements. Mirror of [`write_slice_le`]:
/// zero-copy on LE hosts, per-element conversion elsewhere.
pub fn read_vec_le<T: Pod>(r: &mut impl Read, n: usize) -> Result<Vec<T>> {
    let mut v = vec![T::default(); n];
    #[cfg(target_endian = "little")]
    {
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, n * T::ELEM_BYTES)
        };
        r.read_exact(bytes)?;
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut buf = [0u8; 8192];
        let per = buf.len() / T::ELEM_BYTES;
        for chunk in v.chunks_mut(per) {
            let want = chunk.len() * T::ELEM_BYTES;
            r.read_exact(&mut buf[..want])?;
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = T::get_le(&buf[i * T::ELEM_BYTES..]);
            }
        }
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// FNV-1a 64 checksums
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64 over a byte stream. Chosen over CRC for its
/// trivial spec (two constants) — tools/tcsr_v2.py mirrors it verbatim.
#[derive(Clone, Copy)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(FNV_OFFSET)
    }
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }
    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// FNV-1a 64 of a POD slice's *little-endian* byte image — always equal
/// to the checksum of the bytes as they appear on disk, regardless of
/// host endianness.
pub fn fnv_of_slice<T: Pod>(xs: &[T]) -> u64 {
    let mut h = Fnv64::new();
    #[cfg(target_endian = "little")]
    {
        let bytes = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
        };
        h.update(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut b = [0u8; 8];
        for &x in xs {
            x.put_le(&mut b);
            h.update(&b[..T::ELEM_BYTES]);
        }
    }
    h.finish()
}

// ---------------------------------------------------------------------------
// Segment: owned-or-mapped CSR array storage
// ---------------------------------------------------------------------------

/// One CSR array, either owned in RAM or a zero-copy view into a shared
/// file mapping. Derefs to `[T]`, so every existing consumer (`seg[i]`,
/// `seg[lo..hi]`, `.iter()`, `.windows(2)`, `.len()`) works unchanged;
/// only construction sites know the difference.
///
/// The `Mapped` variant exists only on little-endian Unix: there the
/// on-disk LE byte image can be reinterpreted in place. Big-endian hosts
/// always materialize `Owned` vectors through the converting reader.
#[derive(Debug, Clone)]
pub enum Segment<T: Pod> {
    Owned(Vec<T>),
    #[cfg(all(unix, target_endian = "little"))]
    Mapped {
        map: Arc<Mmap>,
        byte_offset: usize,
        len: usize,
    },
}

impl<T: Pod> Segment<T> {
    /// Zero-copy view of `len` elements at `byte_offset` into `map`.
    /// Panics if the span is misaligned or out of bounds — callers
    /// (the v2 reader) have already validated the layout, so either
    /// would be an internal logic error, not a data error.
    #[cfg(all(unix, target_endian = "little"))]
    pub fn mapped(map: Arc<Mmap>, byte_offset: usize, len: usize) -> Segment<T> {
        let end = byte_offset
            .checked_add(len.checked_mul(T::ELEM_BYTES).expect("segment size overflow"))
            .expect("segment span overflow");
        assert!(end <= map.len(), "segment span exceeds mapping");
        let base = map.as_slice().as_ptr() as usize + byte_offset;
        assert_eq!(base % std::mem::align_of::<T>(), 0, "segment misaligned");
        Segment::Mapped { map, byte_offset, len }
    }

    pub fn as_slice(&self) -> &[T] {
        self
    }

    pub fn is_mapped(&self) -> bool {
        match self {
            Segment::Owned(_) => false,
            #[cfg(all(unix, target_endian = "little"))]
            Segment::Mapped { .. } => true,
        }
    }

    /// Heap bytes this segment pins (0 when it is a file-backed view —
    /// the pages are reclaimable cache, not owned allocation).
    pub fn owned_bytes(&self) -> u64 {
        match self {
            Segment::Owned(v) => (v.len() * T::ELEM_BYTES) as u64,
            #[cfg(all(unix, target_endian = "little"))]
            Segment::Mapped { .. } => 0,
        }
    }
}

impl<T: Pod> std::ops::Deref for Segment<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        match self {
            Segment::Owned(v) => v,
            #[cfg(all(unix, target_endian = "little"))]
            Segment::Mapped { map, byte_offset, len } => unsafe {
                let p = map.as_slice().as_ptr().add(*byte_offset) as *const T;
                std::slice::from_raw_parts(p, *len)
            },
        }
    }
}

impl<T: Pod> From<Vec<T>> for Segment<T> {
    fn from(v: Vec<T>) -> Segment<T> {
        Segment::Owned(v)
    }
}

impl<T: Pod> PartialEq for Segment<T> {
    fn eq(&self, other: &Segment<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod> Default for Segment<T> {
    fn default() -> Segment<T> {
        Segment::Owned(Vec::new())
    }
}

// ---------------------------------------------------------------------------
// Canonical v2 layout
// ---------------------------------------------------------------------------

fn align8(x: u64) -> u64 {
    (x + 7) & !7
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SectionSpan {
    pub kind: u32,
    pub elem_bytes: u32,
    pub offset: u64,
    pub elem_count: u64,
    pub byte_len: u64,
}

#[derive(Debug, Clone)]
pub struct V2Layout {
    /// Fixed header + table + header checksum; first section starts here.
    pub header_bytes: u64,
    pub sections: Vec<SectionSpan>,
    /// Exact file length — the file ends at the last section's end.
    pub total_bytes: u64,
}

/// The one valid layout for a (|V|, |E|, weighted) triple. All arithmetic
/// is checked so an absurd header fails here — before any allocation or
/// file access sized from it.
pub fn layout_for(vcount: u64, ecount: u64, weighted: bool) -> Result<V2Layout> {
    let overflow =
        || anyhow::anyhow!("corrupt header (|V|={vcount}, |E|={ecount} overflow)");
    let n_sections = if weighted { 3u64 } else { 2 };
    let header_bytes = FIXED_HEADER_BYTES + n_sections * TABLE_ENTRY_BYTES + 8;
    let mut sections = Vec::with_capacity(n_sections as usize);
    let mut off = header_bytes; // 48 + 32n: already 8-aligned
    let rows = vcount.checked_add(1).ok_or_else(overflow)?;
    let specs: &[(u32, u32, u64)] = &if weighted {
        vec![(SEC_ROW, 8u32, rows), (SEC_COL, 4, ecount), (SEC_WEIGHTS, 4, ecount)]
    } else {
        vec![(SEC_ROW, 8, rows), (SEC_COL, 4, ecount)]
    };
    for &(kind, elem_bytes, elem_count) in specs {
        off = align8(off);
        let byte_len = elem_count.checked_mul(elem_bytes as u64).ok_or_else(overflow)?;
        let end = off.checked_add(byte_len).ok_or_else(overflow)?;
        sections.push(SectionSpan { kind, elem_bytes, offset: off, elem_count, byte_len });
        off = end;
    }
    Ok(V2Layout { header_bytes, sections, total_bytes: off })
}

fn section_name(kind: u32) -> &'static str {
    match kind {
        SEC_ROW => "row-offsets",
        SEC_COL => "col-indices",
        SEC_WEIGHTS => "weights",
        _ => "unknown",
    }
}

/// Serialize the complete v2 header (fixed fields + table + header
/// checksum) given each section's content checksum.
fn encode_header(
    vcount: u64,
    ecount: u64,
    weighted: bool,
    layout: &V2Layout,
    checksums: &[u64],
) -> Vec<u8> {
    assert_eq!(checksums.len(), layout.sections.len());
    let mut h = Vec::with_capacity(layout.header_bytes as usize);
    h.extend_from_slice(MAGIC);
    h.extend_from_slice(&VERSION_V2.to_le_bytes());
    let flags = if weighted { FLAG_WEIGHTED } else { 0 };
    h.extend_from_slice(&flags.to_le_bytes());
    h.extend_from_slice(&vcount.to_le_bytes());
    h.extend_from_slice(&ecount.to_le_bytes());
    h.extend_from_slice(&(layout.sections.len() as u32).to_le_bytes());
    h.extend_from_slice(&0u32.to_le_bytes()); // reserved
    for (s, &sum) in layout.sections.iter().zip(checksums) {
        h.extend_from_slice(&s.kind.to_le_bytes());
        h.extend_from_slice(&s.elem_bytes.to_le_bytes());
        h.extend_from_slice(&s.offset.to_le_bytes());
        h.extend_from_slice(&s.elem_count.to_le_bytes());
        h.extend_from_slice(&sum.to_le_bytes());
    }
    let mut fnv = Fnv64::new();
    fnv.update(&h);
    h.extend_from_slice(&fnv.finish().to_le_bytes());
    debug_assert_eq!(h.len() as u64, layout.header_bytes);
    h
}

// ---------------------------------------------------------------------------
// Writers
// ---------------------------------------------------------------------------

/// Write a whole in-memory graph as a v2 container. Checksums are
/// computed up front so the file is written strictly sequentially.
pub fn write_csr_v2(g: &CsrGraph, path: &Path) -> Result<u64> {
    let weighted = g.weights.is_some();
    let layout = layout_for(g.vertex_count as u64, g.edge_count() as u64, weighted)?;
    let mut checksums = vec![fnv_of_slice(g.row_offsets.as_slice()), fnv_of_slice(g.col_indices.as_slice())];
    if let Some(ws) = &g.weights {
        checksums.push(fnv_of_slice(ws.as_slice()));
    }
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&encode_header(
        g.vertex_count as u64,
        g.edge_count() as u64,
        weighted,
        &layout,
        &checksums,
    ))?;
    let mut pos = layout.header_bytes;
    let mut pad_to = |w: &mut BufWriter<File>, off: u64, pos: &mut u64| -> Result<()> {
        while *pos < off {
            w.write_all(&[0u8])?;
            *pos += 1;
        }
        Ok(())
    };
    pad_to(&mut w, layout.sections[0].offset, &mut pos)?;
    write_slice_le(&mut w, g.row_offsets.as_slice())?;
    pos += layout.sections[0].byte_len;
    pad_to(&mut w, layout.sections[1].offset, &mut pos)?;
    write_slice_le(&mut w, g.col_indices.as_slice())?;
    pos += layout.sections[1].byte_len;
    if let Some(ws) = &g.weights {
        pad_to(&mut w, layout.sections[2].offset, &mut pos)?;
        write_slice_le(&mut w, ws.as_slice())?;
        pos += layout.sections[2].byte_len;
    }
    w.flush()?;
    debug_assert_eq!(pos, layout.total_bytes);
    Ok(layout.total_bytes)
}

/// Streaming v2 writer for graphs whose edges never fit in RAM at once.
///
/// Construction takes the (vertex-proportional, so RAM-resident by the
/// §12 memory contract) row-offset array and writes a zeroed header
/// placeholder plus the row section; edges are then pushed **in CSR
/// order** (non-decreasing source), streaming col-index bytes straight to
/// the file while weights spool to a sidecar temp file; `finish()`
/// appends the weights section and seeks back to write the real header
/// with the now-known checksums. Peak memory is O(|V|) + IO buffers.
pub struct Csr2Writer {
    w: BufWriter<File>,
    wtmp: Option<(PathBuf, BufWriter<File>)>,
    layout: V2Layout,
    vcount: u64,
    ecount: u64,
    weighted: bool,
    row_fnv: u64,
    col_fnv: Fnv64,
    wei_fnv: Fnv64,
    pushed: u64,
    finished: bool,
}

impl Csr2Writer {
    /// `row_offsets` must be a valid CSR offset array (len |V|+1, starts
    /// at 0, monotone); its last element is |E|.
    pub fn create(path: &Path, row_offsets: &[u64], weighted: bool) -> Result<Csr2Writer> {
        if row_offsets.is_empty() || row_offsets[0] != 0 {
            bail!("row offsets must start with 0");
        }
        if row_offsets.windows(2).any(|w| w[0] > w[1]) {
            bail!("row offsets must be monotone");
        }
        let vcount = (row_offsets.len() - 1) as u64;
        let ecount = *row_offsets.last().unwrap();
        let layout = layout_for(vcount, ecount, weighted)?;
        let f = File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        // Placeholder header + alignment padding; rewritten by finish().
        w.write_all(&vec![0u8; layout.sections[0].offset as usize])?;
        write_slice_le(&mut w, row_offsets)?;
        let wtmp = if weighted {
            let p = path.with_extension("wtmp");
            let tf = File::create(&p).with_context(|| format!("create {p:?}"))?;
            Some((p, BufWriter::new(tf)))
        } else {
            None
        };
        Ok(Csr2Writer {
            w,
            wtmp,
            layout,
            vcount,
            ecount,
            weighted,
            row_fnv: fnv_of_slice(row_offsets),
            col_fnv: Fnv64::new(),
            wei_fnv: Fnv64::new(),
            pushed: 0,
            finished: false,
        })
    }

    /// Append the next edge's destination (and weight, if weighted).
    /// Edges must arrive in CSR order; the caller (SpillBuild's merge)
    /// guarantees it.
    pub fn push_edge(&mut self, dst: u32, weight: f32) -> Result<()> {
        if self.pushed == self.ecount {
            bail!("more edges pushed than the row offsets declare ({})", self.ecount);
        }
        let db = dst.to_le_bytes();
        self.col_fnv.update(&db);
        self.w.write_all(&db)?;
        if let Some((_, tw)) = &mut self.wtmp {
            let wb = weight.to_bits().to_le_bytes();
            self.wei_fnv.update(&wb);
            tw.write_all(&wb)?;
        }
        self.pushed += 1;
        Ok(())
    }

    /// Seal the container: pad, splice in the weights sidecar, rewrite
    /// the real header. Returns the file's total byte length.
    pub fn finish(mut self) -> Result<u64> {
        if self.pushed != self.ecount {
            bail!("{} edges pushed but row offsets declare {}", self.pushed, self.ecount);
        }
        let col = self.layout.sections[1];
        let mut pos = col.offset + col.byte_len;
        if let Some((tpath, tw)) = self.wtmp.take() {
            let wsec = self.layout.sections[2];
            while pos < wsec.offset {
                self.w.write_all(&[0u8])?;
                pos += 1;
            }
            tw.into_inner().map_err(|e| anyhow::anyhow!("flush weights sidecar: {e}"))?;
            let mut tr = BufReader::new(
                File::open(&tpath).with_context(|| format!("reopen {tpath:?}"))?,
            );
            let mut buf = vec![0u8; 1 << 20];
            loop {
                let n = tr.read(&mut buf)?;
                if n == 0 {
                    break;
                }
                self.w.write_all(&buf[..n])?;
                pos += n as u64;
            }
            let _ = std::fs::remove_file(&tpath);
            if pos != wsec.offset + wsec.byte_len {
                bail!("weights sidecar length mismatch");
            }
        }
        if pos != self.layout.total_bytes {
            bail!("stream length mismatch (wrote {pos}, layout says {})", self.layout.total_bytes);
        }
        self.w.flush()?;
        let mut f = self.w.into_inner().map_err(|e| anyhow::anyhow!("flush: {e}"))?;
        let mut checksums = vec![self.row_fnv, self.col_fnv.finish()];
        if self.weighted {
            checksums.push(self.wei_fnv.finish());
        }
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&encode_header(
            self.vcount,
            self.ecount,
            self.weighted,
            &self.layout,
            &checksums,
        ))?;
        f.flush()?;
        self.finished = true;
        Ok(self.layout.total_bytes)
    }
}

impl Drop for Csr2Writer {
    fn drop(&mut self) {
        // On abandoned writes, don't leak the weights sidecar.
        if !self.finished {
            if let Some((p, _)) = self.wtmp.take() {
                let _ = std::fs::remove_file(&p);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reader / GraphStore
// ---------------------------------------------------------------------------

/// How `GraphStore::open_with` should back the CSR arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMode {
    /// Mmap when the platform supports it (little-endian Unix), else
    /// fall back to buffered reads. The default.
    Auto,
    /// Require the mapping; error where unsupported.
    Mmap,
    /// Always materialize owned vectors through the buffered reader.
    Buffered,
}

/// Parsed v2 metadata (no section payloads) — what `totem info` and the
/// corruption tests inspect.
#[derive(Debug, Clone)]
pub struct V2Info {
    pub version: u32,
    pub weighted: bool,
    pub vertices: u64,
    pub edges: u64,
    pub header_bytes: u64,
    pub total_bytes: u64,
    pub sections: Vec<SectionSpan>,
    pub checksums: Vec<u64>,
}

/// Read + fully validate a v2 header (magic, version, flags, canonical
/// layout agreement, header checksum, exact file length). Returns the
/// parse alongside the file, positioned just past the header.
fn read_v2_header(path: &Path, f: &File) -> Result<(V2Info, BufReader<File>)> {
    let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut r = BufReader::new(f.try_clone().with_context(|| format!("reopen {path:?}"))?);
    let mut fixed = [0u8; FIXED_HEADER_BYTES as usize];
    r.read_exact(&mut fixed)
        .with_context(|| format!("{path:?}: truncated header"))?;
    if &fixed[0..8] != MAGIC {
        bail!("{path:?}: not a totem CSR file");
    }
    let ver = u32::get_le(&fixed[8..]);
    if ver != VERSION_V2 {
        bail!("{path:?}: unsupported version {ver}");
    }
    let flags = u32::get_le(&fixed[12..]);
    if flags & !FLAG_WEIGHTED != 0 {
        bail!("{path:?}: corrupt header (unknown flags {flags:#x})");
    }
    let weighted = flags & FLAG_WEIGHTED != 0;
    let vcount = u64::get_le(&fixed[16..]);
    let ecount = u64::get_le(&fixed[24..]);
    let n_sections = u32::get_le(&fixed[32..]);
    let reserved = u32::get_le(&fixed[36..]);
    if reserved != 0 {
        bail!("{path:?}: corrupt header (reserved field != 0)");
    }
    let layout = layout_for(vcount, ecount, weighted)
        .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    if n_sections as usize != layout.sections.len() {
        bail!(
            "{path:?}: corrupt header ({n_sections} sections declared, layout has {})",
            layout.sections.len()
        );
    }
    if file_len < layout.header_bytes {
        bail!(
            "{path:?}: truncated header — {} bytes needed, file holds {file_len}",
            layout.header_bytes
        );
    }
    let mut table = vec![0u8; (n_sections as u64 * TABLE_ENTRY_BYTES) as usize];
    r.read_exact(&mut table)
        .with_context(|| format!("{path:?}: truncated header"))?;
    let mut sumb = [0u8; 8];
    r.read_exact(&mut sumb)
        .with_context(|| format!("{path:?}: truncated header"))?;
    let stored_header_fnv = u64::get_le(&sumb);
    let mut fnv = Fnv64::new();
    fnv.update(&fixed);
    fnv.update(&table);
    if fnv.finish() != stored_header_fnv {
        bail!("{path:?}: corrupt header (checksum mismatch)");
    }
    // The table must agree with the canonical layout exactly.
    let mut checksums = Vec::with_capacity(layout.sections.len());
    for (i, want) in layout.sections.iter().enumerate() {
        let e = &table[i * TABLE_ENTRY_BYTES as usize..];
        let got = SectionSpan {
            kind: u32::get_le(&e[0..]),
            elem_bytes: u32::get_le(&e[4..]),
            offset: u64::get_le(&e[8..]),
            elem_count: u64::get_le(&e[16..]),
            byte_len: u64::get_le(&e[16..])
                .checked_mul(u32::get_le(&e[4..]) as u64)
                .unwrap_or(u64::MAX),
        };
        if got != *want {
            bail!(
                "{path:?}: corrupt header (section {} is {:?}, canonical layout says {:?})",
                i,
                got,
                want
            );
        }
        checksums.push(u64::get_le(&e[24..]));
    }
    if file_len < layout.total_bytes {
        bail!(
            "{path:?}: truncated CSR file — layout needs {} bytes, file holds {file_len}",
            layout.total_bytes
        );
    }
    if file_len > layout.total_bytes {
        bail!("{path:?}: {} trailing bytes after CSR payload", file_len - layout.total_bytes);
    }
    Ok((
        V2Info {
            version: ver,
            weighted,
            vertices: vcount,
            edges: ecount,
            header_bytes: layout.header_bytes,
            total_bytes: layout.total_bytes,
            sections: layout.sections,
            checksums,
        },
        r,
    ))
}

/// Parse and validate a v2 header without loading sections.
pub fn describe_v2(path: &Path) -> Result<V2Info> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    read_v2_header(path, &f).map(|(info, _)| info)
}

/// Peek a `.tcsr` file's container version (1 or 2); errors on non-totem
/// files. Used for version dispatch and CLI input sniffing.
pub fn peek_version(path: &Path) -> Result<u32> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut head = [0u8; 12];
    let mut r = BufReader::new(f);
    r.read_exact(&mut head)
        .with_context(|| format!("{path:?}: truncated header"))?;
    if &head[0..8] != MAGIC {
        bail!("{path:?}: not a totem CSR file");
    }
    Ok(u32::get_le(&head[8..]))
}

/// Whether `path` starts with the `.tcsr` magic (any version).
pub fn is_tcsr(path: &Path) -> bool {
    let mut head = [0u8; 8];
    match File::open(path) {
        Ok(mut f) => f.read_exact(&mut head).is_ok() && &head == MAGIC,
        Err(_) => false,
    }
}

/// Narrow a section's element count for indexing/allocation. The header
/// arithmetic is u64-checked in [`layout_for`], but a count that is valid
/// as u64 can still exceed this platform's address space (a 32-bit host
/// opening a >4G-element container); a bare `as usize` truncated these
/// silently — with `verify=false` that meant a short read and a corrupt
/// graph, not an error (ISSUE 9 satellite bugfix). Checking `byte_len`
/// too keeps `read_vec_le`'s `n * ELEM_BYTES` from overflowing `usize`.
fn sec_elems(path: &Path, s: &SectionSpan) -> Result<usize> {
    if usize::try_from(s.byte_len).is_err() {
        return Err(anyhow::Error::from(IngestError::CountOverflow {
            what: section_name(s.kind),
            count: s.elem_count,
        })
        .context(format!("{path:?}")));
    }
    usize::try_from(s.elem_count).map_err(|_| {
        anyhow::Error::from(IngestError::CountOverflow {
            what: section_name(s.kind),
            count: s.elem_count,
        })
        .context(format!("{path:?}"))
    })
}

/// Narrow the declared vertex count for `CsrGraph::vertex_count`.
fn vertices_usize(path: &Path, vertices: u64) -> Result<usize> {
    usize::try_from(vertices).map_err(|_| {
        anyhow::Error::from(IngestError::CountOverflow { what: "vertex", count: vertices })
            .context(format!("{path:?}"))
    })
}

fn check_padding_zero(path: &Path, bytes: &[u8], at: u64) -> Result<()> {
    if bytes.iter().any(|&b| b != 0) {
        bail!("{path:?}: corrupt CSR file (non-zero padding at offset {at})");
    }
    Ok(())
}

/// A CSR graph plus how it is backed. The graph's sections are either
/// zero-copy views into a shared mapping (`is_mapped()`) or owned
/// vectors; everything downstream sees a plain [`CsrGraph`].
pub struct GraphStore {
    graph: CsrGraph,
    mapped: bool,
}

impl GraphStore {
    /// Open with defaults: auto mmap, checksums verified.
    pub fn open(path: &Path) -> Result<GraphStore> {
        GraphStore::open_with(path, LoadMode::Auto, true)
    }

    /// Open a `.tcsr` container (v1 or v2). v1 files always load through
    /// the buffered legacy reader; v2 honors `mode`. `verify` controls
    /// the per-section checksum pass — skipping it on the mmap path means
    /// no page is faulted before the algorithm touches it, which is the
    /// point of out-of-core loading for |E| ≫ RAM graphs.
    pub fn open_with(path: &Path, mode: LoadMode, verify: bool) -> Result<GraphStore> {
        match peek_version(path)? {
            VERSION_V1 => {
                let graph = super::io::read_csr_v1(path)?;
                Ok(GraphStore { graph, mapped: false })
            }
            VERSION_V2 => Self::open_v2(path, mode, verify),
            other => bail!("{path:?}: unsupported version {other}"),
        }
    }

    fn open_v2(path: &Path, mode: LoadMode, verify: bool) -> Result<GraphStore> {
        let f = File::open(path).with_context(|| format!("open {path:?}"))?;
        let (info, reader) = read_v2_header(path, &f)?;
        let mappable = mmap_supported() && cfg!(target_endian = "little");
        let want_map = match mode {
            LoadMode::Mmap => {
                if !mappable {
                    bail!("{path:?}: mmap loading is unsupported on this platform");
                }
                true
            }
            LoadMode::Buffered => false,
            LoadMode::Auto => mappable,
        };
        if want_map {
            Self::open_v2_mapped(path, &f, &info, verify)
        } else {
            Self::open_v2_buffered(path, reader, &info, verify)
        }
    }

    #[cfg(all(unix, target_endian = "little"))]
    fn open_v2_mapped(path: &Path, f: &File, info: &V2Info, verify: bool) -> Result<GraphStore> {
        let map = Arc::new(
            Mmap::map_readonly(f).with_context(|| format!("mmap {path:?}"))?,
        );
        map.advise_sequential();
        let bytes = map.as_slice();
        let mut prev_end = info.header_bytes;
        for (s, &sum) in info.sections.iter().zip(&info.checksums) {
            check_padding_zero(path, &bytes[prev_end as usize..s.offset as usize], prev_end)?;
            if verify {
                let mut fnv = Fnv64::new();
                fnv.update(&bytes[s.offset as usize..(s.offset + s.byte_len) as usize]);
                if fnv.finish() != sum {
                    bail!(
                        "{path:?}: corrupt {} section (checksum mismatch)",
                        section_name(s.kind)
                    );
                }
            }
            prev_end = s.offset + s.byte_len;
        }
        // The mapping succeeded, so file_len (== layout.total_bytes) fits
        // the address space and every offset below it does too; sec_elems
        // still gates the counts so the invariant is checked, not assumed.
        let row = &info.sections[0];
        let col = &info.sections[1];
        let row_offsets =
            Segment::<u64>::mapped(map.clone(), row.offset as usize, sec_elems(path, row)?);
        let col_indices =
            Segment::<u32>::mapped(map.clone(), col.offset as usize, sec_elems(path, col)?);
        let weights = if info.weighted {
            let w = &info.sections[2];
            Some(Segment::<f32>::mapped(map, w.offset as usize, sec_elems(path, w)?))
        } else {
            None
        };
        let graph = CsrGraph {
            vertex_count: vertices_usize(path, info.vertices)?,
            row_offsets,
            col_indices,
            weights,
        };
        graph
            .validate()
            .map_err(|e| anyhow::anyhow!("{path:?}: corrupt CSR: {e}"))?;
        Ok(GraphStore { graph, mapped: true })
    }

    #[cfg(not(all(unix, target_endian = "little")))]
    fn open_v2_mapped(path: &Path, _f: &File, _info: &V2Info, _verify: bool) -> Result<GraphStore> {
        bail!("{path:?}: mmap loading is unsupported on this platform");
    }

    fn open_v2_buffered(
        path: &Path,
        mut r: BufReader<File>,
        info: &V2Info,
        verify: bool,
    ) -> Result<GraphStore> {
        // The reader sits just past the header; sections follow in file
        // order with only alignment padding between them.
        let mut pos = info.header_bytes;
        let mut skip_padding = |r: &mut BufReader<File>, pos: &mut u64, to: u64| -> Result<()> {
            if *pos < to {
                let mut pad = vec![0u8; (to - *pos) as usize];
                r.read_exact(&mut pad)?;
                check_padding_zero(path, &pad, *pos)?;
                *pos = to;
            }
            Ok(())
        };
        let row = &info.sections[0];
        skip_padding(&mut r, &mut pos, row.offset)?;
        let row_offsets: Vec<u64> = read_vec_le(&mut r, sec_elems(path, row)?)
            .with_context(|| format!("{path:?}: truncated row offsets"))?;
        pos += row.byte_len;
        let col = &info.sections[1];
        skip_padding(&mut r, &mut pos, col.offset)?;
        let col_indices: Vec<u32> = read_vec_le(&mut r, sec_elems(path, col)?)
            .with_context(|| format!("{path:?}: truncated column indices"))?;
        pos += col.byte_len;
        let weights: Option<Vec<f32>> = if info.weighted {
            let wsec = &info.sections[2];
            skip_padding(&mut r, &mut pos, wsec.offset)?;
            Some(
                read_vec_le(&mut r, sec_elems(path, wsec)?)
                    .with_context(|| format!("{path:?}: truncated weights"))?,
            )
        } else {
            None
        };
        if verify {
            let sums = [
                fnv_of_slice(&row_offsets),
                fnv_of_slice(&col_indices),
                weights.as_deref().map(fnv_of_slice).unwrap_or(0),
            ];
            for (i, s) in info.sections.iter().enumerate() {
                if sums[i] != info.checksums[i] {
                    bail!(
                        "{path:?}: corrupt {} section (checksum mismatch)",
                        section_name(s.kind)
                    );
                }
            }
        }
        let graph = CsrGraph {
            vertex_count: vertices_usize(path, info.vertices)?,
            row_offsets: row_offsets.into(),
            col_indices: col_indices.into(),
            weights: weights.map(Segment::from),
        };
        graph
            .validate()
            .map_err(|e| anyhow::anyhow!("{path:?}: corrupt CSR: {e}"))?;
        Ok(GraphStore { graph, mapped: false })
    }

    pub fn graph(&self) -> &CsrGraph {
        &self.graph
    }

    pub fn into_graph(self) -> CsrGraph {
        self.graph
    }

    /// True when the CSR sections are file-backed mmap views.
    pub fn is_mapped(&self) -> bool {
        self.mapped
    }
}

impl LoadMode {
    pub fn parse(s: &str) -> Result<LoadMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(LoadMode::Auto),
            "mmap" => Ok(LoadMode::Mmap),
            "buffered" | "read" => Ok(LoadMode::Buffered),
            _ => Err(format!("unknown store mode '{s}' (auto|mmap|buffered)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_reference_vectors() {
        // Standard FNV-1a 64 test vectors — these pin the exact constants
        // the Python mirror (tools/tcsr_v2.py) must reproduce.
        let of = |s: &str| {
            let mut h = Fnv64::new();
            h.update(s.as_bytes());
            h.finish()
        };
        assert_eq!(of(""), 0xcbf29ce484222325);
        assert_eq!(of("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(of("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv_of_slice_matches_le_bytes() {
        let xs: Vec<u32> = vec![1, 0xdeadbeef, 42];
        let mut bytes = Vec::new();
        for x in &xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let mut h = Fnv64::new();
        h.update(&bytes);
        assert_eq!(fnv_of_slice(&xs), h.finish());
    }

    #[test]
    fn le_slice_roundtrip_all_types() {
        fn rt<T: Pod>(xs: Vec<T>) {
            let mut buf = Vec::new();
            write_slice_le(&mut buf, &xs).unwrap();
            assert_eq!(buf.len(), xs.len() * T::ELEM_BYTES);
            let back: Vec<T> = read_vec_le(&mut &buf[..], xs.len()).unwrap();
            assert_eq!(back, xs);
        }
        rt(vec![0u32, 1, u32::MAX, 0x01020304]);
        rt(vec![0u64, u64::MAX, 0x0102030405060708]);
        rt(vec![0f32, -1.5, f32::MAX, f32::MIN_POSITIVE]);
    }

    #[test]
    fn le_encoding_is_byte_exact() {
        let mut buf = Vec::new();
        write_slice_le(&mut buf, &[0x01020304u32]).unwrap();
        assert_eq!(buf, vec![0x04, 0x03, 0x02, 0x01], "explicitly little-endian");
    }

    #[test]
    fn layout_is_canonical_and_aligned() {
        let l = layout_for(5, 9, true).unwrap();
        // 3 sections: header = 40 + 96 + 8 = 144.
        assert_eq!(l.header_bytes, 144);
        assert_eq!(l.sections[0], SectionSpan { kind: SEC_ROW, elem_bytes: 8, offset: 144, elem_count: 6, byte_len: 48 });
        assert_eq!(l.sections[1].offset, 192);
        assert_eq!(l.sections[1].byte_len, 36);
        // col ends at 228 → weights padded up to 232.
        assert_eq!(l.sections[2].offset, 232);
        assert_eq!(l.total_bytes, 232 + 36);
        for s in &l.sections {
            assert_eq!(s.offset % 8, 0, "8-byte aligned sections");
        }
        // unweighted: two sections, no trailing pad.
        let l2 = layout_for(5, 9, false).unwrap();
        assert_eq!(l2.header_bytes, 112);
        assert_eq!(l2.total_bytes, 112 + 48 + 36);
    }

    #[test]
    fn layout_rejects_overflowing_counts() {
        assert!(layout_for(u64::MAX, 8, false).is_err());
        assert!(layout_for(8, u64::MAX / 2, true).is_err());
    }

    #[test]
    fn count_overflow_error_names_the_section() {
        let e = IngestError::CountOverflow { what: "col-indices", count: 1 << 40 };
        let msg = e.to_string();
        assert!(msg.contains("col-indices") && msg.contains("overflows"), "{msg}");
        assert_eq!(e, IngestError::CountOverflow { what: "col-indices", count: 1 << 40 });
    }

    #[test]
    fn sec_elems_passes_addressable_counts_through() {
        let s = SectionSpan { kind: SEC_COL, elem_bytes: 4, offset: 0, elem_count: 9, byte_len: 36 };
        assert_eq!(sec_elems(Path::new("x.tcsr"), &s).unwrap(), 9);
    }

    // On 32-bit hosts a >4G-element section must fail typed instead of
    // truncating the allocation and short-reading the file. (The same
    // counts are unrepresentable in a real file on a 64-bit test host, so
    // this path is exercised only where it can actually fire.)
    #[cfg(target_pointer_width = "32")]
    #[test]
    fn sec_elems_rejects_counts_beyond_address_space() {
        let s = SectionSpan {
            kind: SEC_COL,
            elem_bytes: 4,
            offset: 0,
            elem_count: 5u64 << 30,
            byte_len: 20u64 << 30,
        };
        let msg = format!("{:#}", sec_elems(Path::new("x.tcsr"), &s).unwrap_err());
        assert!(msg.contains("overflows"), "{msg}");
        assert!(vertices_usize(Path::new("x.tcsr"), u64::MAX).is_err());
    }

    #[test]
    fn segment_derefs_like_a_slice() {
        let s: Segment<u64> = vec![0u64, 3, 7].into();
        assert_eq!(s.len(), 3);
        assert_eq!(s[1], 3);
        assert_eq!(&s[1..], &[3, 7]);
        assert_eq!(s.windows(2).count(), 2);
        assert!(!s.is_mapped());
        assert_eq!(s.owned_bytes(), 24);
        let t: Segment<u64> = vec![0u64, 3, 7].into();
        assert_eq!(s, t);
    }
}
