//! Compressed Sparse Row graph representation (paper §4.3.1).
//!
//! Space-efficient `O(|V| + |E|)` adjacency: `row_offsets[v]..row_offsets[v+1]`
//! indexes into `col_indices` (destination vertex of each out-edge).
//! Weights are optional and parallel to `col_indices` (SSSP only).
//!
//! Vertex ids are `u32` (graphs up to 4B vertices); edge offsets are `u64`
//! (graphs beyond 4B edges), mirroring the paper's `vid`/`eid` sizing rule
//! in §4.3.3.
//!
//! The three CSR arrays are stored as [`Segment`]s — owned vectors for
//! in-memory builds, zero-copy mmap views when loaded from a `.tcsr` v2
//! container (DESIGN.md §12). `Segment` derefs to a slice, so consumers
//! are storage-agnostic.

use super::store::Segment;
use super::IngestError;

pub type VertexId = u32;

/// An edge list staging structure; the mutable builder-side twin of
/// [`CsrGraph`].
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    pub vertex_count: usize,
    pub edges: Vec<(VertexId, VertexId)>,
    pub weights: Option<Vec<f32>>,
}

impl EdgeList {
    pub fn new(vertex_count: usize) -> Self {
        EdgeList { vertex_count, edges: Vec::new(), weights: None }
    }

    pub fn push(&mut self, src: VertexId, dst: VertexId) {
        debug_assert!((src as usize) < self.vertex_count);
        debug_assert!((dst as usize) < self.vertex_count);
        self.edges.push((src, dst));
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }
}

/// Immutable CSR graph.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    pub vertex_count: usize,
    pub row_offsets: Segment<u64>,
    pub col_indices: Segment<VertexId>,
    pub weights: Option<Segment<f32>>,
}

impl CsrGraph {
    /// Build from an edge list with counting sort — `O(|V| + |E|)`.
    /// Weight order follows edge order.
    ///
    /// Panics on out-of-range endpoints or a mismatched weight array —
    /// trusted in-process callers only. File/CLI ingest goes through
    /// [`CsrGraph::try_from_edge_list`], which surfaces the same checks
    /// as a typed error (`EdgeList::push` only `debug_assert!`s bounds,
    /// so untrusted data used to reach the counting sort and panic on an
    /// index in release builds).
    pub fn from_edge_list(el: &EdgeList) -> Self {
        match Self::try_from_edge_list(el) {
            Ok(g) => g,
            Err(e) => panic!("invalid edge list: {e}"),
        }
    }

    /// Checked build: validates every endpoint against `vertex_count` and
    /// the weight tally against the edge tally before sorting, returning
    /// a typed error naming the offending edge.
    pub fn try_from_edge_list(el: &EdgeList) -> Result<Self, IngestError> {
        let v = el.vertex_count;
        if let Some(ws) = &el.weights {
            if ws.len() != el.edges.len() {
                return Err(IngestError::WeightCountMismatch {
                    edges: el.edges.len() as u64,
                    weights: ws.len() as u64,
                });
            }
        }
        for (i, &(s, d)) in el.edges.iter().enumerate() {
            if s as usize >= v || d as usize >= v {
                return Err(IngestError::EdgeOutOfRange {
                    index: i as u64,
                    src: s,
                    dst: d,
                    vertex_count: v,
                });
            }
        }
        let mut deg = vec![0u64; v + 1];
        for &(s, _) in &el.edges {
            deg[s as usize + 1] += 1;
        }
        for i in 0..v {
            deg[i + 1] += deg[i];
        }
        let row_offsets = deg.clone();
        let mut cursor = deg;
        let mut col_indices = vec![0u32; el.edges.len()];
        let mut weights = el.weights.as_ref().map(|_| vec![0f32; el.edges.len()]);
        for (i, &(s, d)) in el.edges.iter().enumerate() {
            let slot = cursor[s as usize];
            col_indices[slot as usize] = d;
            if let (Some(w_out), Some(w_in)) = (&mut weights, &el.weights) {
                w_out[slot as usize] = w_in[i];
            }
            cursor[s as usize] += 1;
        }
        Ok(CsrGraph {
            vertex_count: v,
            row_offsets: row_offsets.into(),
            col_indices: col_indices.into(),
            weights: weights.map(Segment::from),
        })
    }

    #[inline]
    pub fn edge_count(&self) -> usize {
        self.col_indices.len()
    }

    #[inline]
    pub fn out_degree(&self, v: VertexId) -> u64 {
        self.row_offsets[v as usize + 1] - self.row_offsets[v as usize]
    }

    /// Out-neighborhood of `v` as a slice of destination ids.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let lo = self.row_offsets[v as usize] as usize;
        let hi = self.row_offsets[v as usize + 1] as usize;
        &self.col_indices[lo..hi]
    }

    /// Edge-parallel weights for `v` (panics if the graph is unweighted).
    #[inline]
    pub fn edge_weights(&self, v: VertexId) -> &[f32] {
        let lo = self.row_offsets[v as usize] as usize;
        let hi = self.row_offsets[v as usize + 1] as usize;
        &self.weights.as_ref().expect("unweighted graph")[lo..hi]
    }

    /// Iterate `(src, dst)` over all edges.
    pub fn iter_edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.vertex_count as u32)
            .flat_map(move |v| self.neighbors(v).iter().map(move |&d| (v, d)))
    }

    /// Degree of every vertex, as a dense array.
    pub fn out_degrees(&self) -> Vec<u64> {
        (0..self.vertex_count)
            .map(|v| self.row_offsets[v + 1] - self.row_offsets[v])
            .collect()
    }

    /// Reversed graph: edge (u,v) becomes (v,u). Weights follow edges.
    /// Used to derive in-edge CSR for pull-based algorithms (PageRank §7.1).
    pub fn reverse(&self) -> CsrGraph {
        let v = self.vertex_count;
        let mut deg = vec![0u64; v + 1];
        for &d in self.col_indices.iter() {
            deg[d as usize + 1] += 1;
        }
        for i in 0..v {
            deg[i + 1] += deg[i];
        }
        let row_offsets = deg.clone();
        let mut cursor = deg;
        let mut col_indices = vec![0u32; self.col_indices.len()];
        let mut weights = self.weights.as_ref().map(|_| vec![0f32; self.col_indices.len()]);
        for s in 0..v as u32 {
            let lo = self.row_offsets[s as usize] as usize;
            for (k, &d) in self.neighbors(s).iter().enumerate() {
                let slot = cursor[d as usize] as usize;
                col_indices[slot] = s;
                if let (Some(w_out), Some(w_in)) = (&mut weights, &self.weights) {
                    w_out[slot] = w_in[lo + k];
                }
                cursor[d as usize] += 1;
            }
        }
        CsrGraph {
            vertex_count: v,
            row_offsets: row_offsets.into(),
            col_indices: col_indices.into(),
            weights: weights.map(Segment::from),
        }
    }

    /// Undirected view: every edge doubled (u,v)+(v,u), as the paper does
    /// for Connected Components (§9.4 Table 5 note).
    pub fn to_undirected(&self) -> CsrGraph {
        let mut el = EdgeList::new(self.vertex_count);
        el.edges.reserve(self.edge_count() * 2);
        let mut w = self.weights.as_ref().map(|_| Vec::with_capacity(self.edge_count() * 2));
        for s in 0..self.vertex_count as u32 {
            let lo = self.row_offsets[s as usize] as usize;
            for (k, &d) in self.neighbors(s).iter().enumerate() {
                el.edges.push((s, d));
                el.edges.push((d, s));
                if let (Some(wv), Some(ws)) = (&mut w, &self.weights) {
                    wv.push(ws[lo + k]);
                    wv.push(ws[lo + k]);
                }
            }
        }
        el.weights = w;
        CsrGraph::from_edge_list(&el)
    }

    /// Bytes used by the CSR arrays themselves (paper §4.3.3:
    /// `eid × |V| + vid × |E| (+ 4 × |E| weights)`).
    pub fn footprint_bytes(&self) -> u64 {
        let base = (self.row_offsets.len() * 8 + self.col_indices.len() * 4) as u64;
        base + self.weights.as_ref().map_or(0, |w| (w.len() * 4) as u64)
    }

    /// Heap bytes the CSR arrays actually pin — 0 for mmap-backed
    /// segments, whose pages are reclaimable file cache (DESIGN.md §12.6
    /// memory accounting distinguishes the two).
    pub fn owned_bytes(&self) -> u64 {
        self.row_offsets.owned_bytes()
            + self.col_indices.owned_bytes()
            + self.weights.as_ref().map_or(0, |w| w.owned_bytes())
    }

    /// True when any CSR array is a file-backed mmap view.
    pub fn is_mapped(&self) -> bool {
        self.row_offsets.is_mapped()
            || self.col_indices.is_mapped()
            || self.weights.as_ref().is_some_and(|w| w.is_mapped())
    }

    /// Structural invariant check (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.row_offsets.len() != self.vertex_count + 1 {
            return Err("row_offsets length".into());
        }
        if self.row_offsets[0] != 0 {
            return Err("row_offsets[0] != 0".into());
        }
        if *self.row_offsets.last().unwrap() != self.col_indices.len() as u64 {
            return Err("row_offsets tail != |E|".into());
        }
        if self.row_offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err("row_offsets not monotone".into());
        }
        if self.col_indices.iter().any(|&d| (d as usize) >= self.vertex_count) {
            return Err("col index out of range".into());
        }
        if let Some(w) = &self.weights {
            if w.len() != self.col_indices.len() {
                return Err("weights length mismatch".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> CsrGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn csr_structure() {
        let g = diamond();
        g.validate().unwrap();
        assert_eq!(g.vertex_count, 4);
        assert_eq!(g.edge_count(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[3]);
        assert_eq!(g.neighbors(3), &[] as &[u32]);
        assert_eq!(g.out_degree(0), 2);
    }

    #[test]
    fn reverse_inverts_edges() {
        let g = diamond();
        let r = g.reverse();
        r.validate().unwrap();
        assert_eq!(r.neighbors(3), &[1, 2]);
        assert_eq!(r.neighbors(1), &[0]);
        assert_eq!(r.neighbors(0), &[] as &[u32]);
        // double reverse = original edge multiset
        let rr = r.reverse();
        let mut e1: Vec<_> = g.iter_edges().collect();
        let mut e2: Vec<_> = rr.iter_edges().collect();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2);
    }

    #[test]
    fn weights_follow_reverse() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(1, 2);
        el.weights = Some(vec![10.0, 20.0]);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(g.edge_weights(0), &[10.0]);
        let r = g.reverse();
        assert_eq!(r.edge_weights(1), &[10.0]);
        assert_eq!(r.edge_weights(2), &[20.0]);
    }

    #[test]
    fn undirected_doubles_edges() {
        let g = diamond();
        let u = g.to_undirected();
        u.validate().unwrap();
        assert_eq!(u.edge_count(), 8);
        assert_eq!(u.neighbors(3), &[1, 2]);
    }

    #[test]
    fn iter_edges_complete() {
        let g = diamond();
        let edges: Vec<_> = g.iter_edges().collect();
        assert_eq!(edges, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
    }

    #[test]
    fn footprint_matches_formula() {
        let g = diamond();
        assert_eq!(g.footprint_bytes(), (5 * 8 + 4 * 4) as u64);
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_edge_list(&EdgeList::new(0));
        g.validate().unwrap();
        assert_eq!(g.edge_count(), 0);
    }

    #[test]
    fn try_from_edge_list_names_the_offending_edge() {
        let mut el = EdgeList::new(3);
        el.edges.push((0, 1));
        el.edges.push((2, 9)); // dst out of range
        let err = CsrGraph::try_from_edge_list(&el).unwrap_err();
        assert_eq!(
            err,
            crate::graph::IngestError::EdgeOutOfRange { index: 1, src: 2, dst: 9, vertex_count: 3 }
        );
        assert!(err.to_string().contains("out of declared range"), "{err}");
    }

    #[test]
    fn try_from_edge_list_checks_weight_tally() {
        let mut el = EdgeList::new(2);
        el.edges.push((0, 1));
        el.weights = Some(vec![1.0, 2.0]);
        let err = CsrGraph::try_from_edge_list(&el).unwrap_err();
        assert_eq!(
            err,
            crate::graph::IngestError::WeightCountMismatch { edges: 1, weights: 2 }
        );
    }

    #[test]
    #[should_panic(expected = "out of declared range")]
    fn from_edge_list_panics_with_typed_message_on_bad_ids() {
        // The unchecked constructor used to fail with a raw index panic
        // deep in the counting sort (release builds); it now reports the
        // offending edge even on the panicking path.
        let mut el = EdgeList::new(2);
        el.edges.push((0, 7));
        let _ = CsrGraph::from_edge_list(&el);
    }

    #[test]
    fn self_loops_and_multi_edges_preserved() {
        let mut el = EdgeList::new(2);
        el.push(0, 0);
        el.push(0, 1);
        el.push(0, 1);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(g.neighbors(0), &[0, 1, 1]);
    }
}
