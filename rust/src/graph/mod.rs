//! Graph substrate: CSR representation (paper §4.3.1), synthetic workload
//! generators (Table 2), serialization, and topology statistics.

pub mod csr;
pub mod generator;
pub mod io;
pub mod properties;

pub use csr::{CsrGraph, EdgeList, VertexId};
pub use generator::{rmat, uniform, with_random_weights, RmatParams, Workload};
