//! Graph substrate: CSR representation (paper §4.3.1), synthetic workload
//! generators (Table 2), serialization, the out-of-core `.tcsr` v2
//! container (DESIGN.md §12), the streaming mutation log (DESIGN.md §14),
//! and topology statistics.

pub mod csr;
pub mod delta;
pub mod generator;
pub mod ingest;
pub mod io;
pub mod properties;
pub mod store;

pub use csr::{CsrGraph, EdgeList, VertexId};
pub use generator::{rmat, uniform, with_random_weights, RmatParams, Workload};
pub use store::{GraphStore, LoadMode, Segment};

/// Typed errors raised by the ingest paths (file parsing, CLI entry
/// points, streaming builds). Every variant names the offending datum so
/// a failed multi-hour conversion says *which* edge or tally was wrong —
/// these used to be silent truncations or release-mode index panics
/// (ISSUE 7 satellite bugs).
#[derive(Debug, Clone, PartialEq)]
pub enum IngestError {
    /// An edge endpoint is `>= vertex_count`. `index` is the 0-based
    /// position in the input edge stream.
    EdgeOutOfRange { index: u64, src: u32, dst: u32, vertex_count: usize },
    /// A `p <V> <E>` header declared `declared` edges but the file held
    /// `actual` — a truncated or padded edge list.
    EdgeCountMismatch { declared: u64, actual: u64 },
    /// The weight array does not parallel the edge array.
    WeightCountMismatch { edges: u64, weights: u64 },
    /// A weighted edge follows unweighted ones (or vice versa) at input
    /// line `line` (1-based).
    MixedWeights { line: u64 },
    /// A declared count does not fit this platform's `usize` (or its
    /// derived size arithmetic overflows) — a 32-bit host reading a
    /// >4G-element container, or a corrupt header. Narrowing with a bare
    /// `as usize` used to truncate these silently (ISSUE 9 satellite
    /// bugfix).
    CountOverflow { what: &'static str, count: u64 },
}

impl std::fmt::Display for IngestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IngestError::EdgeOutOfRange { index, src, dst, vertex_count } => write!(
                f,
                "edge #{index} ({src} -> {dst}) has a vertex id out of declared range {vertex_count}"
            ),
            IngestError::EdgeCountMismatch { declared, actual } => write!(
                f,
                "edge count mismatch: header declares {declared} edges but the file holds {actual}"
            ),
            IngestError::WeightCountMismatch { edges, weights } => {
                write!(f, "weight count mismatch: {edges} edges but {weights} weights")
            }
            IngestError::MixedWeights { line } => {
                write!(f, "line {line}: mixed weighted/unweighted edges")
            }
            IngestError::CountOverflow { what, count } => write!(
                f,
                "{what} count {count} overflows this platform's addressable size"
            ),
        }
    }
}

impl std::error::Error for IngestError {}
