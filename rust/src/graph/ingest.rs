//! Bounded-memory CSR construction: external sort by source vertex in
//! fixed-size spill runs (DESIGN.md §12.5).
//!
//! [`SpillBuild`] accepts an arbitrary-order edge stream while holding at
//! most `run_edges` edges in RAM: each full buffer is stably sorted by
//! source and spilled to a run file; `finish_*` k-way-merges the runs
//! (keyed `(src, run_index)`) straight into a [`Csr2Writer`], so the only
//! vertex-proportional state is the degree/offset array and the only
//! edge-proportional state lives on disk. The merge order provably equals
//! the in-memory counting sort's: runs are consecutive stream chunks, the
//! in-run sort is stable, and the run-index tie-break restores stream
//! order across chunks — a streamed conversion is bit-identical to an
//! in-memory build of the same stream.

use super::csr::CsrGraph;
use super::generator::Workload;
use super::io;
use super::store::Csr2Writer;
use super::IngestError;
use anyhow::{bail, Context, Result};
use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// Default spill-run capacity: 2^23 edges ≈ 96 MB of staging for
/// weighted streams — scale-25 R-MAT (512 M edges) spills ~64 runs.
pub const DEFAULT_SPILL_EDGES: usize = 1 << 23;

/// What a streamed conversion did — surfaced by `totem convert` and the
/// memory-accounting bench so the "edge staging is bounded by the
/// spill-run size" claim is checkable, not asserted.
#[derive(Debug, Clone, Copy)]
pub struct ConvertStats {
    pub vertices: usize,
    pub edges: u64,
    pub weighted: bool,
    /// Spill runs written (1 when the whole stream fit in one buffer —
    /// the finish flush still goes through disk; 0 for an empty stream).
    pub runs: usize,
    pub run_edges: usize,
    /// Peak bytes of in-RAM edge staging (buffer high-water mark) —
    /// bounded by `run_edges × 12`.
    pub peak_staging_bytes: u64,
    /// Bytes of the finished `.tcsr` container, when one was written.
    pub bytes_written: u64,
}

/// In-RAM bytes per buffered edge record.
const REC_BYTES: u64 = 12;

struct RunCursor {
    r: BufReader<File>,
    remaining: u64,
    cur: (u32, u32, f32),
}

/// External-sort CSR builder. See the module docs for the memory and
/// ordering contract.
pub struct SpillBuild {
    vertex_count: usize,
    weighted: bool,
    run_edges: usize,
    tmp_dir: PathBuf,
    buf: Vec<(u32, u32, f32)>,
    /// Out-degree histogram, prefix-summed into row offsets at finish.
    degrees: Vec<u64>,
    runs: Vec<PathBuf>,
    total: u64,
    peak_staging_bytes: u64,
}

impl SpillBuild {
    /// `tmp_parent` hosts the spill-run directory (same filesystem as the
    /// output is the sensible choice); `run_edges` is the staging bound.
    pub fn new(
        vertex_count: usize,
        weighted: bool,
        run_edges: usize,
        tmp_parent: &Path,
    ) -> Result<SpillBuild> {
        if run_edges == 0 {
            bail!("spill run size must be positive");
        }
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let tmp_dir = tmp_parent.join(format!(
            "totem_spill_{}_{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&tmp_dir)
            .with_context(|| format!("create spill dir {tmp_dir:?}"))?;
        Ok(SpillBuild {
            vertex_count,
            weighted,
            run_edges,
            tmp_dir,
            buf: Vec::with_capacity(run_edges.min(1 << 20)),
            degrees: vec![0u64; vertex_count + 1],
            runs: Vec::new(),
            total: 0,
            peak_staging_bytes: 0,
        })
    }

    fn rec_disk_bytes(&self) -> usize {
        if self.weighted {
            12
        } else {
            8
        }
    }

    /// Append one edge (weight ignored for unweighted builds). Bounds are
    /// checked here — the typed error names the offending edge, where the
    /// pre-ISSUE-7 path carried bad ids all the way into a release-mode
    /// index panic.
    pub fn push(&mut self, src: u32, dst: u32, weight: f32) -> Result<()> {
        if src as usize >= self.vertex_count || dst as usize >= self.vertex_count {
            return Err(IngestError::EdgeOutOfRange {
                index: self.total,
                src,
                dst,
                vertex_count: self.vertex_count,
            }
            .into());
        }
        self.degrees[src as usize + 1] += 1;
        self.buf.push((src, dst, weight));
        self.total += 1;
        self.peak_staging_bytes = self.peak_staging_bytes.max(self.buf.len() as u64 * REC_BYTES);
        if self.buf.len() >= self.run_edges {
            self.spill_run()?;
        }
        Ok(())
    }

    fn spill_run(&mut self) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        // Stable by-source sort: equal sources keep stream order.
        self.buf.sort_by_key(|&(s, _, _)| s);
        let path = self.tmp_dir.join(format!("run_{:05}.bin", self.runs.len()));
        let f = File::create(&path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        for &(s, d, wt) in &self.buf {
            w.write_all(&s.to_le_bytes())?;
            w.write_all(&d.to_le_bytes())?;
            if self.weighted {
                w.write_all(&wt.to_bits().to_le_bytes())?;
            }
        }
        w.flush()?;
        self.runs.push(path);
        self.buf.clear();
        Ok(())
    }

    fn read_rec(&self, c: &mut RunCursor) -> Result<bool> {
        if c.remaining == 0 {
            return Ok(false);
        }
        let mut b = [0u8; 12];
        let n = self.rec_disk_bytes();
        c.r.read_exact(&mut b[..n]).context("truncated spill run")?;
        let s = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        let d = u32::from_le_bytes([b[4], b[5], b[6], b[7]]);
        let wt = if self.weighted {
            f32::from_bits(u32::from_le_bytes([b[8], b[9], b[10], b[11]]))
        } else {
            0.0
        };
        c.cur = (s, d, wt);
        c.remaining -= 1;
        Ok(true)
    }

    /// Merge all runs in `(src, run_index)` order into `emit`.
    fn merge(mut self, mut emit: impl FnMut(u32, u32, f32) -> Result<()>) -> Result<ConvertStats> {
        self.spill_run()?;
        let run_paths = std::mem::take(&mut self.runs);
        let n_runs = run_paths.len();
        let mut cursors: Vec<RunCursor> = Vec::with_capacity(n_runs);
        let mut counts = vec![0u64; n_runs];
        // Per-run edge counts: all runs are full except possibly the last.
        let mut left = self.total;
        for c in counts.iter_mut() {
            *c = left.min(self.run_edges as u64);
            left -= *c;
        }
        debug_assert_eq!(left, 0);
        let mut heap: BinaryHeap<std::cmp::Reverse<(u32, usize)>> = BinaryHeap::new();
        for (i, path) in run_paths.iter().enumerate() {
            let f = File::open(path).with_context(|| format!("open spill run {path:?}"))?;
            let mut cur = RunCursor { r: BufReader::new(f), remaining: counts[i], cur: (0, 0, 0.0) };
            if self.read_rec(&mut cur)? {
                heap.push(std::cmp::Reverse((cur.cur.0, i)));
            }
            cursors.push(cur);
        }
        let mut emitted = 0u64;
        while let Some(std::cmp::Reverse((_, i))) = heap.pop() {
            let (s, d, wt) = cursors[i].cur;
            emit(s, d, wt)?;
            emitted += 1;
            if self.read_rec(&mut cursors[i])? {
                heap.push(std::cmp::Reverse((cursors[i].cur.0, i)));
            }
        }
        if emitted != self.total {
            bail!("spill merge emitted {emitted} of {} edges", self.total);
        }
        Ok(ConvertStats {
            vertices: self.vertex_count,
            edges: self.total,
            weighted: self.weighted,
            runs: n_runs,
            run_edges: self.run_edges,
            peak_staging_bytes: self.peak_staging_bytes,
            bytes_written: 0,
        })
    }

    fn row_offsets(&self) -> Vec<u64> {
        let mut ro = self.degrees.clone();
        for i in 0..self.vertex_count {
            ro[i + 1] += ro[i];
        }
        ro
    }

    /// Stream the merged CSR into a v2 container at `out`.
    pub fn finish_to_file(self, out: &Path) -> Result<ConvertStats> {
        let row_offsets = self.row_offsets();
        let weighted = self.weighted;
        let mut writer = Some(Csr2Writer::create(out, &row_offsets, weighted)?);
        drop(row_offsets);
        let mut stats = self.merge(|_, d, wt| {
            writer.as_mut().expect("writer live during merge").push_edge(d, wt)
        })?;
        stats.bytes_written = writer.take().expect("writer live").finish()?;
        Ok(stats)
    }

    /// Materialize the merged CSR in memory — the test-sized path used to
    /// prove spill/merge equivalence against the counting sort.
    pub fn finish_graph(self) -> Result<(CsrGraph, ConvertStats)> {
        let row_offsets = self.row_offsets();
        let vertex_count = self.vertex_count;
        let weighted = self.weighted;
        let total = self.total as usize;
        let mut col_indices = Vec::with_capacity(total);
        let mut weights = if weighted { Some(Vec::with_capacity(total)) } else { None };
        let stats = self.merge(|_, d, wt| {
            col_indices.push(d);
            if let Some(ws) = &mut weights {
                ws.push(wt);
            }
            Ok(())
        })?;
        let g = CsrGraph {
            vertex_count,
            row_offsets: row_offsets.into(),
            col_indices: col_indices.into(),
            weights: weights.map(Into::into),
        };
        g.validate().map_err(|e| anyhow::anyhow!("spill-built CSR invalid: {e}"))?;
        Ok((g, stats))
    }
}

impl Drop for SpillBuild {
    fn drop(&mut self) {
        for p in &self.runs {
            let _ = std::fs::remove_file(p);
        }
        let _ = std::fs::remove_dir_all(&self.tmp_dir);
    }
}

/// Stream a synthetic workload into a v2 container with bounded staging.
pub fn convert_workload_to_tcsr(
    w: &Workload,
    seed: u64,
    weighted: bool,
    out: &Path,
    run_edges: usize,
    tmp_parent: &Path,
) -> Result<ConvertStats> {
    let (vcount, _ecount) = w.dimensions();
    let mut b = SpillBuild::new(vcount, weighted, run_edges, tmp_parent)?;
    w.stream(seed, weighted, &mut |s, d, wt| b.push(s, d, wt.unwrap_or(0.0)))?;
    b.finish_to_file(out)
}

/// Stream a text edge list into a v2 container with bounded staging. Two
/// passes: a scan to learn (|V|, weightedness) and validate tallies, then
/// the spill build.
pub fn convert_edge_list_to_tcsr(
    input: &Path,
    out: &Path,
    run_edges: usize,
    tmp_parent: &Path,
) -> Result<ConvertStats> {
    let summary = io::scan_edge_list(input)?;
    let mut b = SpillBuild::new(summary.vertex_count, summary.weighted, run_edges, tmp_parent)?;
    io::stream_edge_list(input, &mut |s, d, wt| b.push(s, d, wt.unwrap_or(0.0)))?;
    b.finish_to_file(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, with_random_weights, RmatParams};
    use crate::graph::EdgeList;

    fn tmp() -> PathBuf {
        let dir = std::env::temp_dir().join("totem_ingest_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spill_equals_counting_sort(el: &EdgeList, run_edges: usize) {
        let expect = CsrGraph::from_edge_list(el);
        let mut b =
            SpillBuild::new(el.vertex_count, el.weights.is_some(), run_edges, &tmp()).unwrap();
        for (i, &(s, d)) in el.edges.iter().enumerate() {
            let w = el.weights.as_ref().map_or(0.0, |ws| ws[i]);
            b.push(s, d, w).unwrap();
        }
        let (g, stats) = b.finish_graph().unwrap();
        assert_eq!(g.row_offsets, expect.row_offsets, "run_edges={run_edges}");
        assert_eq!(g.col_indices, expect.col_indices, "run_edges={run_edges}");
        assert_eq!(g.weights, expect.weights, "run_edges={run_edges}");
        assert!(
            stats.peak_staging_bytes <= run_edges as u64 * REC_BYTES,
            "staging {} exceeds bound {}",
            stats.peak_staging_bytes,
            run_edges as u64 * REC_BYTES
        );
    }

    #[test]
    fn spill_build_equals_counting_sort_across_run_sizes() {
        let mut el = rmat(&RmatParams::paper(7, 21));
        with_random_weights(&mut el, 16, 22);
        for run_edges in [7, 100, 10_000] {
            spill_equals_counting_sort(&el, run_edges);
        }
        let el_unweighted = rmat(&RmatParams::paper(7, 23));
        spill_equals_counting_sort(&el_unweighted, 64);
    }

    #[test]
    fn spill_run_count_and_staging_bound() {
        let el = rmat(&RmatParams::paper(6, 5)); // 1024 edges
        let mut b = SpillBuild::new(el.vertex_count, false, 100, &tmp()).unwrap();
        for &(s, d) in &el.edges {
            b.push(s, d, 0.0).unwrap();
        }
        let (_, stats) = b.finish_graph().unwrap();
        assert_eq!(stats.runs, 11, "1024 edges / 100 per run");
        assert_eq!(stats.edges, 1024);
        assert_eq!(stats.peak_staging_bytes, 100 * REC_BYTES);
    }

    #[test]
    fn spill_push_rejects_out_of_range_edges() {
        let mut b = SpillBuild::new(4, false, 8, &tmp()).unwrap();
        b.push(0, 3, 0.0).unwrap();
        let err = b.push(1, 9, 0.0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("edge #1"), "{msg}");
        assert!(msg.contains("out of declared range 4"), "{msg}");
    }

    #[test]
    fn spill_tmp_dir_is_cleaned_up() {
        let parent = tmp();
        let before: usize = std::fs::read_dir(&parent).unwrap().count();
        {
            let mut b = SpillBuild::new(8, false, 2, &parent).unwrap();
            for i in 0..6u32 {
                b.push(i % 8, (i + 1) % 8, 0.0).unwrap();
            }
            let _ = b.finish_graph().unwrap();
        }
        let after: usize = std::fs::read_dir(&parent).unwrap().count();
        assert_eq!(before, after, "spill dir removed");
        // and on abandonment (drop without finish)
        {
            let mut b = SpillBuild::new(8, false, 2, &parent).unwrap();
            b.push(0, 1, 0.0).unwrap();
            b.push(1, 2, 0.0).unwrap();
            b.push(2, 3, 0.0).unwrap();
        }
        assert_eq!(std::fs::read_dir(&parent).unwrap().count(), before);
    }

    #[test]
    fn empty_and_zero_edge_builds() {
        let b = SpillBuild::new(0, false, 4, &tmp()).unwrap();
        let (g, stats) = b.finish_graph().unwrap();
        assert_eq!(g.vertex_count, 0);
        assert_eq!(stats.edges, 0);
        let b = SpillBuild::new(5, true, 4, &tmp()).unwrap();
        let (g, _) = b.finish_graph().unwrap();
        assert_eq!(g.vertex_count, 5);
        assert_eq!(g.edge_count(), 0);
        assert!(g.weights.is_some());
    }
}
