//! Benchmark/CLI harness support: run any algorithm by name on any
//! workload under any engine configuration, with repeated measurements and
//! TEPS accounting (paper §5 "Evaluation Metrics" / "Data Collection").

use crate::alg::incremental::{pagerank_residual_push, BfsRelax};
use crate::alg::program::WarmStart;
use crate::alg::{
    bc::Bc, bfs::Bfs, cc::Cc, kcore::KCore, labelprop::LabelProp, pagerank::Pagerank, ppr::Ppr,
    sssp::Sssp, triangles::Triangles, widest::Widest,
};
use crate::alg::Algorithm;
use crate::engine::state::StateArray;
use crate::engine::{self, EngineConfig, RunResult};
use crate::partition::Placement;
use crate::graph::delta::AppliedDelta;
use crate::graph::generator::{weight_seed, with_random_weights, WEIGHT_MAX_DEFAULT};
use crate::graph::{CsrGraph, Workload};
use crate::stats;
use anyhow::{bail, Result};

/// The evaluated algorithms: the paper's five (§5 + §9.4), the
/// widest-path program that proves the typed vertex-program API
/// (DESIGN.md §10), and the motif/community family on the edge-centric
/// kernels (DESIGN.md §15): triangle counting, k-core, label
/// propagation, and personalized PageRank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgKind {
    Bfs,
    Pagerank,
    Sssp,
    Bc,
    Cc,
    Widest,
    Triangles,
    Kcore,
    Labelprop,
    Ppr,
}

pub const ALL_ALGS: [AlgKind; 10] = [
    AlgKind::Bfs,
    AlgKind::Pagerank,
    AlgKind::Sssp,
    AlgKind::Bc,
    AlgKind::Cc,
    AlgKind::Widest,
    AlgKind::Triangles,
    AlgKind::Kcore,
    AlgKind::Labelprop,
    AlgKind::Ppr,
];

impl AlgKind {
    pub fn parse(name: &str) -> Result<AlgKind, String> {
        match name.to_ascii_lowercase().as_str() {
            "bfs" => Ok(AlgKind::Bfs),
            "pagerank" | "pr" => Ok(AlgKind::Pagerank),
            "sssp" => Ok(AlgKind::Sssp),
            "bc" => Ok(AlgKind::Bc),
            "cc" => Ok(AlgKind::Cc),
            "widest" | "wsp" => Ok(AlgKind::Widest),
            "triangles" | "tc" => Ok(AlgKind::Triangles),
            "kcore" => Ok(AlgKind::Kcore),
            "labelprop" | "lp" => Ok(AlgKind::Labelprop),
            "ppr" => Ok(AlgKind::Ppr),
            _ => Err(format!(
                "unknown algorithm '{name}' \
                 (bfs|pagerank|sssp|bc|cc|widest|triangles|kcore|labelprop|ppr)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgKind::Bfs => "bfs",
            AlgKind::Pagerank => "pagerank",
            AlgKind::Sssp => "sssp",
            AlgKind::Bc => "bc",
            AlgKind::Cc => "cc",
            AlgKind::Widest => "widest",
            AlgKind::Triangles => "triangles",
            AlgKind::Kcore => "kcore",
            AlgKind::Labelprop => "labelprop",
            AlgKind::Ppr => "ppr",
        }
    }

    pub fn needs_weights(&self) -> bool {
        matches!(self, AlgKind::Sssp | AlgKind::Widest)
    }

    /// Does the run interpret `RunSpec::rounds` (fixed-iteration
    /// algorithms)? Everything else runs to quiescence.
    pub fn uses_rounds(&self) -> bool {
        matches!(self, AlgKind::Pagerank | AlgKind::Ppr | AlgKind::Labelprop)
    }

    /// Does the run interpret `RunSpec::source`?
    pub fn needs_source(&self) -> bool {
        matches!(
            self,
            AlgKind::Bfs | AlgKind::Sssp | AlgKind::Bc | AlgKind::Widest | AlgKind::Ppr
        )
    }

    /// Incremental-recompute strategy class (DESIGN.md §14.3) — an
    /// exhaustive match, so adding an `AlgKind` is a compile error here
    /// instead of a silent fall-through into a wildcard arm of
    /// [`incremental_rerun`].
    pub fn incremental_class(&self) -> IncClass {
        match self {
            // monotone min/max relaxations: warm start unless the batch
            // really deleted edge copies
            AlgKind::Bfs | AlgKind::Sssp | AlgKind::Cc | AlgKind::Widest => IncClass::Monotone,
            // residual push (host-side Gauss–Seidel)
            AlgKind::Pagerank => IncClass::Residual,
            // no incremental form: BC's two-cycle sweeps; triangle counts,
            // coreness, and labels are not monotone under insertion; PPR
            // is served per-query from the epoch cache instead (§15.4)
            AlgKind::Bc
            | AlgKind::Triangles
            | AlgKind::Kcore
            | AlgKind::Labelprop
            | AlgKind::Ppr => IncClass::Unsupported,
        }
    }
}

/// How an algorithm can be recomputed after a mutation batch — the
/// decision table behind [`incremental_rerun`], factored out so the
/// classification is a single exhaustive `match` per [`AlgKind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IncClass {
    /// Monotone warm start through the engine (insert-only batches).
    Monotone,
    /// PageRank residual push.
    Residual,
    /// Always a full cold rerun.
    Unsupported,
}

/// Sentinel: pick the highest-degree vertex as the source (Graph500
/// samples sources with non-zero degree; the max-degree hub is the
/// deterministic equivalent).
pub const AUTO_SOURCE: u32 = u32::MAX;

/// Run parameters beyond the engine config.
#[derive(Debug, Clone, Copy)]
pub struct RunSpec {
    pub alg: AlgKind,
    pub source: u32,
    pub rounds: usize,
}

impl RunSpec {
    pub fn new(alg: AlgKind) -> RunSpec {
        RunSpec { alg, source: AUTO_SOURCE, rounds: crate::alg::pagerank::DEFAULT_ROUNDS }
    }
    pub fn with_source(mut self, s: u32) -> Self {
        self.source = s;
        self
    }
    pub fn with_rounds(mut self, r: usize) -> Self {
        self.rounds = r;
        self
    }
}

/// Build a workload graph, attaching weights when the algorithm needs them.
pub fn build_workload(w: Workload, seed: u64, alg: AlgKind) -> CsrGraph {
    let mut el = w.generate(seed);
    if alg.needs_weights() {
        // Same max/seed convention as the streaming path (Workload::stream),
        // so `totem convert` output is bit-identical to the in-memory build.
        with_random_weights(&mut el, WEIGHT_MAX_DEFAULT, weight_seed(seed));
    }
    CsrGraph::from_edge_list(&el)
}

/// Resolve the run's source vertex (AUTO → highest-degree vertex).
pub fn resolve_source(g: &CsrGraph, spec: &RunSpec) -> u32 {
    if spec.source != AUTO_SOURCE {
        return spec.source;
    }
    (0..g.vertex_count as u32)
        .max_by_key(|&v| g.out_degree(v))
        .unwrap_or(0)
}

/// Run one algorithm and let it account its own traversed edges — TEPS
/// dispatch now lives on the [`Algorithm`] trait (each vertex program
/// reports its formula), not in a stringly-typed match.
fn run_counted<A: Algorithm>(
    g: &CsrGraph,
    alg: &mut A,
    cfg: &EngineConfig,
    rounds: usize,
) -> Result<(RunResult, u64)> {
    let r = engine::run(g, alg, cfg)?;
    let traversed = alg.traversed_edges(&r.output, g, rounds);
    Ok((r, traversed))
}

/// Dispatch one engine run by algorithm kind. Returns the run result and
/// the traversed-edge count for TEPS.
pub fn run_alg(g: &CsrGraph, spec: RunSpec, cfg: &EngineConfig) -> Result<(RunResult, u64)> {
    let spec = RunSpec { source: resolve_source(g, &spec), ..spec };
    let rounds = if spec.alg.uses_rounds() { spec.rounds } else { 1 };
    match spec.alg {
        AlgKind::Bfs => run_counted(g, &mut Bfs::new(spec.source), cfg, rounds),
        AlgKind::Pagerank => run_counted(g, &mut Pagerank::new(spec.rounds), cfg, rounds),
        AlgKind::Sssp => run_counted(g, &mut Sssp::new(spec.source), cfg, rounds),
        AlgKind::Bc => run_counted(g, &mut Bc::new(spec.source), cfg, rounds),
        AlgKind::Cc => run_counted(g, &mut Cc::new(), cfg, rounds),
        AlgKind::Widest => run_counted(g, &mut Widest::new(spec.source), cfg, rounds),
        AlgKind::Triangles => run_counted(g, &mut Triangles::new(), cfg, rounds),
        AlgKind::Kcore => run_counted(g, &mut KCore::new(), cfg, rounds),
        AlgKind::Labelprop => run_counted(g, &mut LabelProp::new(spec.rounds), cfg, rounds),
        AlgKind::Ppr => run_counted(g, &mut Ppr::new(spec.source, spec.rounds), cfg, rounds),
    }
}

/// How [`incremental_rerun`] recomputed after a mutation batch
/// (DESIGN.md §14.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Recompute {
    /// Monotone warm start through the engine: prior values injected,
    /// only the mutation-touched frontier re-activated. Bit-identical to
    /// a cold run.
    WarmStart,
    /// PageRank residual push (host-side, deterministic), with the number
    /// of Gauss–Seidel sweeps it took to quiesce.
    ResidualPush { sweeps: usize },
    /// Full cold rerun, with the reason incremental was declined.
    Full(FullReason),
}

/// Why [`incremental_rerun`] fell back to a full recompute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FullReason {
    /// The batch really removed edge copies: the prior fixed point no
    /// longer over-approximates the new one, and min/max relaxation
    /// cannot move values *away* from the reduce direction.
    EffectiveDeletes,
    /// The algorithm has no incremental form
    /// ([`AlgKind::incremental_class`] says [`IncClass::Unsupported`]).
    Unsupported,
}

/// Result of one incremental recompute.
#[derive(Debug, Clone)]
pub struct IncrementalRun {
    /// Per-vertex output on the post-batch graph (same dtype contract as
    /// `RunResult::output` for this algorithm).
    pub output: StateArray,
    /// Which strategy actually ran.
    pub recompute: Recompute,
    /// Engine supersteps (warm/full) or push sweeps (residual).
    pub supersteps: usize,
}

/// Recompute `spec.alg` on the post-batch graph `g_new`, reusing `prior`
/// (the same algorithm's converged output on the *pre-batch* graph) where
/// correctness allows — strategy table in [`Recompute`] / DESIGN.md §14.3.
///
/// `spec.source` must already be resolved (the prior run fixed it against
/// the pre-mutation graph; re-resolving `AUTO_SOURCE` against `g_new`
/// could silently pick a different hub and invalidate `prior`).
pub fn incremental_rerun(
    g_new: &CsrGraph,
    spec: RunSpec,
    cfg: &EngineConfig,
    prior: &StateArray,
    delta: &AppliedDelta,
) -> Result<IncrementalRun> {
    if spec.alg.needs_source() && spec.source == AUTO_SOURCE {
        bail!(
            "incremental_rerun needs a resolved source for {} — resolve AUTO against the \
             pre-mutation graph first (resolve_source)",
            spec.alg.name()
        );
    }
    let full = |reason: FullReason| -> Result<IncrementalRun> {
        let (r, _) = run_alg(g_new, spec, cfg)?;
        Ok(IncrementalRun {
            output: r.output,
            recompute: Recompute::Full(reason),
            supersteps: r.supersteps,
        })
    };
    match spec.alg.incremental_class() {
        IncClass::Unsupported => full(FullReason::Unsupported),
        IncClass::Residual => {
            let (ranks, sweeps) = pagerank_residual_push(g_new, prior.try_as_f32()?);
            Ok(IncrementalRun {
                output: StateArray::F32(ranks),
                recompute: Recompute::ResidualPush { sweeps },
                supersteps: sweeps,
            })
        }
        IncClass::Monotone if delta.effective_deletes => full(FullReason::EffectiveDeletes),
        IncClass::Monotone => {
            let warm = WarmStart { prior: prior.clone(), seeds: delta.touched.clone() };
            let r = match spec.alg {
                AlgKind::Bfs => {
                    engine::run(g_new, &mut BfsRelax::new(spec.source).with_warm_start(warm)?, cfg)?
                }
                AlgKind::Sssp => {
                    engine::run(g_new, &mut Sssp::new(spec.source).with_warm_start(warm)?, cfg)?
                }
                AlgKind::Cc => engine::run(g_new, &mut Cc::new().with_warm_start(warm)?, cfg)?,
                AlgKind::Widest => {
                    engine::run(g_new, &mut Widest::new(spec.source).with_warm_start(warm)?, cfg)?
                }
                _ => unreachable!("only Monotone algorithms reach the warm-start arm"),
            };
            Ok(IncrementalRun {
                output: r.output,
                recompute: Recompute::WarmStart,
                supersteps: r.supersteps,
            })
        }
    }
}

/// Repeated measurement of one configuration.
pub struct Measured {
    /// Mean makespan over reps (Eq. 2 accounting).
    pub makespan_secs: f64,
    pub makespan_ci95: f64,
    /// Mean TEPS over reps.
    pub teps: f64,
    /// Bottleneck-processor compute seconds (mean).
    pub bottleneck_secs: f64,
    /// Communication seconds (mean).
    pub comm_secs: f64,
    /// Realized communication-overlap factor, mean over reps (0 for the
    /// synchronous engine; DESIGN.md §4.2).
    pub overlap_factor: f64,
    /// Vertex migrations by the dynamic α controller (last rep).
    pub migrations: usize,
    /// Supersteps in which some element ran bottom-up (last rep; 0 unless
    /// the config enables direction optimization — DESIGN.md §8).
    pub pull_steps: usize,
    /// Intra-partition vertex placement the run used (DESIGN.md §9) —
    /// surfaced so benchmark reports can label per-placement rows.
    pub placement: Placement,
    /// Widest CPU-element thread count the run used (DESIGN.md §11) — so
    /// scaling reports can label per-thread rows without re-deriving it
    /// from the element list. Clamped to the worker-pool cap
    /// (`MAX_POOL_WORKERS`), which `EngineConfig::validate` enforces, so
    /// the label always matches the threads that actually ran.
    pub threads: usize,
    /// Peak RSS of the measured reps (VmHWM; `None` off Linux) — scoped
    /// to this `measure` call by `PeakRssProbe` (watermark reset after
    /// warmup), so back-to-back measurements in one process don't inherit
    /// each other's peaks. When `/proc/self/clear_refs` is unavailable
    /// this degrades to the probe's documented baseline+delta lower
    /// bound. DESIGN.md §12.6.
    pub peak_rss_bytes: Option<u64>,
    /// CSR-array bytes of the input graph (paper §4.3.3 formula).
    pub graph_bytes: u64,
    /// Heap bytes the input graph's CSR arrays actually pin — 0 when the
    /// graph is an mmap view of a `.tcsr` container (reclaimable file
    /// cache, not committed memory).
    pub graph_owned_bytes: u64,
    /// Summed per-partition footprints (graph copies + inbox/outbox +
    /// state) from the last rep.
    pub partition_bytes: u64,
    /// Last run's full result (partition stats etc. are deterministic
    /// given the seed, so any rep's copy is representative).
    pub last: RunResult,
    pub traversed: u64,
}

/// Run `reps` repetitions (after one warmup) and aggregate.
pub fn measure(g: &CsrGraph, spec: RunSpec, cfg: &EngineConfig, reps: usize) -> Result<Measured> {
    let reps = reps.max(1);
    // warmup (compiles accelerator programs, faults pages)
    let _ = run_alg(g, spec, cfg)?;
    // open the peak-RSS region after warmup: the measured peak covers the
    // reps, not graph construction or a previous measurement's high water
    let rss = crate::util::mem::PeakRssProbe::start();
    let mut makespans = Vec::with_capacity(reps);
    let mut bottleneck = Vec::with_capacity(reps);
    let mut comm = Vec::with_capacity(reps);
    let mut teps = Vec::with_capacity(reps);
    let mut overlap = Vec::with_capacity(reps);
    let mut last: Option<(RunResult, u64)> = None;
    for _ in 0..reps {
        let (r, tr) = run_alg(g, spec, cfg)?;
        let mk = r.makespan_secs().max(1e-12);
        makespans.push(mk);
        bottleneck.push(r.metrics.bottleneck_compute_secs());
        comm.push(r.metrics.comm_secs());
        overlap.push(r.metrics.overlap_factor());
        teps.push(tr as f64 / mk);
        last = Some((r, tr));
    }
    let (last, traversed) = last.unwrap();
    let partition_bytes = last.footprints.iter().map(|fp| fp.total()).sum();
    Ok(Measured {
        peak_rss_bytes: rss.peak(),
        graph_bytes: g.footprint_bytes(),
        graph_owned_bytes: g.owned_bytes(),
        partition_bytes,
        makespan_secs: stats::mean(&makespans),
        makespan_ci95: stats::ci95(&makespans),
        teps: stats::mean(&teps),
        bottleneck_secs: stats::mean(&bottleneck),
        comm_secs: stats::mean(&comm),
        overlap_factor: stats::mean(&overlap),
        migrations: last.metrics.migrations,
        pull_steps: last.metrics.pull_steps(),
        placement: cfg.placement,
        threads: cfg.max_cpu_threads().min(crate::util::threadpool::MAX_POOL_WORKERS),
        last,
        traversed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Strategy;

    #[test]
    fn parse_alg_names() {
        assert_eq!(AlgKind::parse("BFS").unwrap(), AlgKind::Bfs);
        assert_eq!(AlgKind::parse("pr").unwrap(), AlgKind::Pagerank);
        assert_eq!(AlgKind::parse("widest").unwrap(), AlgKind::Widest);
        assert_eq!(AlgKind::parse("WSP").unwrap(), AlgKind::Widest);
        assert_eq!(AlgKind::parse("tc").unwrap(), AlgKind::Triangles);
        assert_eq!(AlgKind::parse("kcore").unwrap(), AlgKind::Kcore);
        assert_eq!(AlgKind::parse("LP").unwrap(), AlgKind::Labelprop);
        assert_eq!(AlgKind::parse("ppr").unwrap(), AlgKind::Ppr);
        let err = AlgKind::parse("dijkstra").unwrap_err();
        for a in ALL_ALGS {
            assert!(err.contains(a.name()), "error should list {}", a.name());
        }
        assert!(AlgKind::Widest.needs_weights());
        // round-trip: every kind parses back from its own name
        for a in ALL_ALGS {
            assert_eq!(AlgKind::parse(a.name()).unwrap(), a);
        }
    }

    #[test]
    fn incremental_rerun_picks_the_right_strategy() {
        use crate::graph::delta::{apply, DeltaBatch};
        let g = build_workload(Workload::Rmat(7), 9, AlgKind::Bfs);
        let cfg = EngineConfig::host_only(1);
        let spec = RunSpec::new(AlgKind::Bfs);
        let spec = spec.with_source(resolve_source(&g, &spec));
        let (r0, _) = run_alg(&g, spec, &cfg).unwrap();

        // insert-only → warm start, bit-identical to a cold rerun
        let ins = DeltaBatch::seeded(&g, 12, 0.0, 5);
        let a = apply(&g, &ins).unwrap();
        let inc = incremental_rerun(&a.graph, spec, &cfg, &r0.output, &a).unwrap();
        assert_eq!(inc.recompute, Recompute::WarmStart);
        let (cold, _) = run_alg(&a.graph, spec, &cfg).unwrap();
        assert_eq!(inc.output.as_i32(), cold.output.as_i32());

        // effective delete → full fallback
        let del = DeltaBatch::seeded(&g, 12, 1.0, 5);
        let b = apply(&g, &del).unwrap();
        assert!(b.effective_deletes);
        let inc = incremental_rerun(&b.graph, spec, &cfg, &r0.output, &b).unwrap();
        assert_eq!(inc.recompute, Recompute::Full(FullReason::EffectiveDeletes));

        // BC has no incremental form
        let (bc0, _) = run_alg(&g, RunSpec::new(AlgKind::Bc).with_source(spec.source), &cfg)
            .unwrap();
        let inc = incremental_rerun(
            &a.graph,
            RunSpec::new(AlgKind::Bc).with_source(spec.source),
            &cfg,
            &bc0.output,
            &a,
        )
        .unwrap();
        assert_eq!(inc.recompute, Recompute::Full(FullReason::Unsupported));

        // an unresolved AUTO source is a typed error, not a wrong answer
        assert!(
            incremental_rerun(&a.graph, RunSpec::new(AlgKind::Bfs), &cfg, &r0.output, &a)
                .is_err()
        );

        // the motif workloads classify Unsupported even for insert-only
        // batches (prior output is ignored on the full-rerun path)
        for alg in [AlgKind::Triangles, AlgKind::Kcore, AlgKind::Labelprop] {
            assert_eq!(alg.incremental_class(), IncClass::Unsupported);
            let inc =
                incremental_rerun(&a.graph, RunSpec::new(alg), &cfg, &r0.output, &a).unwrap();
            assert_eq!(inc.recompute, Recompute::Full(FullReason::Unsupported), "{alg:?}");
        }
        // PPR needs a resolved source like the other source algorithms
        assert_eq!(AlgKind::Ppr.incremental_class(), IncClass::Unsupported);
        assert!(
            incremental_rerun(&a.graph, RunSpec::new(AlgKind::Ppr), &cfg, &r0.output, &a)
                .is_err()
        );
    }

    #[test]
    fn measure_host_only_all_algs() {
        let seed = 3;
        for alg in ALL_ALGS {
            let g = build_workload(Workload::Rmat(8), seed, alg);
            let m = measure(&g, RunSpec::new(alg), &EngineConfig::host_only(1), 2).unwrap();
            assert!(m.makespan_secs > 0.0, "{:?}", alg);
            assert!(m.teps > 0.0, "{:?}", alg);
            assert!(m.traversed > 0, "{:?}", alg);
        }
    }

    #[test]
    fn measure_partitioned() {
        let g = build_workload(Workload::Rmat(9), 5, AlgKind::Bfs);
        let cfg = EngineConfig::cpu_partitions(&[0.6, 0.4], Strategy::High);
        let m = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, 2).unwrap();
        assert!(m.comm_secs >= 0.0);
        assert!((m.last.shares[0] - 0.6).abs() < 0.1);
        assert_eq!(m.overlap_factor, 0.0, "synchronous engine never overlaps");
        assert_eq!(m.migrations, 0);
        assert_eq!(m.placement, Placement::DegreeDesc, "default layout");
    }

    #[test]
    fn measure_reports_configured_placement() {
        let g = build_workload(Workload::Rmat(8), 11, AlgKind::Bfs);
        let cfg = EngineConfig::host_only(1).with_placement(Placement::BfsOrder);
        let m = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, 1).unwrap();
        assert_eq!(m.placement, Placement::BfsOrder);
        assert!(m.teps > 0.0);
    }

    #[test]
    fn measure_direction_optimized_bfs() {
        // A hub-sourced star switches to pull at the first decision point
        // (m_f = hub degree > m_u / α), so pull_steps must be reported.
        let mut el = crate::graph::EdgeList::new(64);
        for i in 1..64u32 {
            el.push(0, i);
            el.push(i, 0);
        }
        let g = crate::graph::CsrGraph::from_edge_list(&el);
        let cfg = EngineConfig::host_only(1).direction_optimized();
        let m = measure(&g, RunSpec::new(AlgKind::Bfs).with_source(0), &cfg, 1).unwrap();
        assert!(m.pull_steps >= 1, "direction heuristic never switched");
        // and push-only runs report zero
        let m2 = measure(&g, RunSpec::new(AlgKind::Bfs).with_source(0), &EngineConfig::host_only(1), 1)
            .unwrap();
        assert_eq!(m2.pull_steps, 0);
    }

    #[test]
    fn measure_reports_memory_accounting() {
        let g = build_workload(Workload::Rmat(8), 3, AlgKind::Bfs);
        let m = measure(&g, RunSpec::new(AlgKind::Bfs), &EngineConfig::host_only(1), 1).unwrap();
        assert_eq!(m.graph_bytes, g.footprint_bytes());
        assert_eq!(m.graph_owned_bytes, m.graph_bytes, "in-memory build owns all arrays");
        assert!(m.partition_bytes >= m.graph_bytes, "partitions hold a graph copy plus state");
        if cfg!(target_os = "linux") {
            assert!(m.peak_rss_bytes.unwrap() > 0, "VmHWM probe");
        }
    }

    #[test]
    fn measure_pipelined_reports_overlap_fields() {
        let g = build_workload(Workload::Rmat(8), 7, AlgKind::Bfs);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand).pipelined();
        let m = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, 1).unwrap();
        assert!((0.0..=1.0).contains(&m.overlap_factor));
        assert!(m.teps > 0.0);
    }
}
