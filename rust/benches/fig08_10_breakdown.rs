//! Figures 8 & 10 (paper §5.2, §6.3.2): breakdown of BFS execution time
//! into computation (bottleneck processor), accelerator compute, and
//! communication — for one and two accelerators, across α and across
//! partitioning strategies.
//!
//! Paper shape: communication is a small fraction of the total after
//! message reduction; the bottleneck processor dominates.

use totem::engine::EngineConfig;
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("fig08_10_breakdown: SKIP (run `make artifacts`)");
        return;
    }
    let scale = args.usize_or("scale", 14).unwrap() as u32;
    let reps = args.usize_or("reps", 2).unwrap();
    let g = build_workload(Workload::Rmat(scale), 42, AlgKind::Bfs);

    // --- Fig 8: RAND partitioning, alpha sweep, 1 and 2 accelerators -------
    let mut t8 = Table::new(
        &format!("Fig 8: BFS time breakdown, RMAT{scale}, RAND partitioning"),
        &["config", "alpha", "total", "cpu compute", "accel compute", "comm", "comm %"],
    );
    let mut rows = Vec::new();
    for accels in [1usize, 2] {
        for alpha in [0.5, 0.6, 0.7, 0.8, 0.9] {
            let cfg =
                EngineConfig::hybrid(accels, alpha, Strategy::Rand).with_artifacts(&artifacts);
            let Ok(m) = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, reps) else {
                continue;
            };
            let r = &m.last;
            let cpu = r.metrics.partition_compute_secs(0);
            let acc: f64 = (1..=accels).map(|p| r.metrics.partition_compute_secs(p)).sum();
            let total = m.makespan_secs;
            t8.row(vec![
                format!("2S{accels}G"),
                format!("{alpha:.1}"),
                fmt_secs(total),
                fmt_secs(cpu),
                fmt_secs(acc),
                fmt_secs(m.comm_secs),
                format!("{:.1}%", 100.0 * m.comm_secs / total),
            ]);
            rows.push(obj(vec![
                ("config", s(&format!("2S{accels}G"))),
                ("alpha", num(alpha)),
                ("total", num(total)),
                ("cpu", num(cpu)),
                ("accel", num(acc)),
                ("comm", num(m.comm_secs)),
            ]));
        }
    }

    // --- Fig 10: strategy comparison at a fixed offload --------------------
    let mut t10 = Table::new(
        &format!("Fig 10: BFS breakdown by strategy, RMAT{scale}, alpha=0.8, 2S1G"),
        &["strategy", "total", "cpu compute", "accel compute", "comm", "cpu verts"],
    );
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        let cfg = EngineConfig::hybrid(1, 0.8, strat).with_artifacts(&artifacts);
        let Ok(m) = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, reps) else {
            continue;
        };
        let r = &m.last;
        t10.row(vec![
            strat.name().to_string(),
            fmt_secs(m.makespan_secs),
            fmt_secs(r.metrics.partition_compute_secs(0)),
            fmt_secs(r.metrics.partition_compute_secs(1)),
            fmt_secs(m.comm_secs),
            r.vertices[0].to_string(),
        ]);
    }

    let md = format!("{}\n{}", t8.markdown(), t10.markdown());
    print!("{md}");
    save("fig08_10_breakdown", &md, &obj(vec![("rows", arr(rows))])).unwrap();
    eprintln!("fig08_10_breakdown: done");
}
