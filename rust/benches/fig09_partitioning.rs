//! Figure 9 (paper §6.3.1): BFS traversal rate under RAND / HIGH / LOW
//! partitioning while varying the share of edges on the CPU, for one and
//! two accelerators, with the host-only rate as the reference line.
//!
//! Measured series reflect this testbed (where the accelerator element is
//! slower than the CPU element — opposite of the paper's GPU); the
//! model-projected series replay the same α/β/|V_p| geometry through
//! Eq. 2 with the paper's Figure-1 reference rates, reproducing the
//! paper's "who wins" shape (HIGH > RAND > LOW for the CPU-bound side).

use totem::engine::EngineConfig;
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::model::{calibrate::beta_of, speedup, ModelParams};
use totem::partition::Strategy;
use totem::report::{fmt_teps, save, Figure, Series, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("fig09_partitioning: SKIP (run `make artifacts`)");
        return;
    }
    let scale = args.usize_or("scale", 14).unwrap() as u32;
    let reps = args.usize_or("reps", 2).unwrap();
    let alphas = args
        .f64_list_or("alphas", &[0.5, 0.6, 0.7, 0.8, 0.9])
        .unwrap();
    let g = build_workload(Workload::Rmat(scale), 42, AlgKind::Bfs);

    let host = measure(&g, RunSpec::new(AlgKind::Bfs), &EngineConfig::host_only(1), reps)
        .expect("host run");
    println!("host-only (2S) rate: {}\n", fmt_teps(host.teps));

    let paper_params = ModelParams::paper_reference();
    let mut table = Table::new(
        &format!("Fig 9: BFS TEPS by strategy and alpha, RMAT{scale}, 2S1G"),
        &[
            "strategy",
            "alpha",
            "measured rate",
            "vs host",
            "cpu-side speedup",
            "model-projected speedup (paper rates)",
        ],
    );
    let mut fig = Figure::new(
        &format!("Fig 9: model-projected hybrid speedup by strategy (RMAT{scale})"),
        "alpha (CPU edge share)",
        "speedup vs host",
    );
    let mut rows = Vec::new();
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        let mut series = Series::new(strat.name());
        for &alpha in &alphas {
            let cfg = EngineConfig::hybrid(1, alpha, strat).with_artifacts(&artifacts);
            let Ok(m) = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, reps) else {
                table.row(vec![
                    strat.name().into(),
                    format!("{alpha:.1}"),
                    "does not fit".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            let r = &m.last;
            let beta = beta_of(r, g.edge_count());
            let projected = speedup(r.shares[0], beta, &paper_params);
            // the paper's super-linear HIGH effect lives on the CPU side:
            // compare the CPU partition's compute time to host-only compute.
            let cpu_speedup =
                host.bottleneck_secs / r.metrics.partition_compute_secs(0).max(1e-12);
            table.row(vec![
                strat.name().into(),
                format!("{alpha:.1}"),
                fmt_teps(m.teps),
                format!("{:.2}x", host.makespan_secs / m.makespan_secs),
                format!("{cpu_speedup:.2}x"),
                format!("{projected:.2}x"),
            ]);
            series.push(alpha, projected);
            rows.push(obj(vec![
                ("strategy", s(strat.name())),
                ("alpha", num(alpha)),
                ("teps", num(m.teps)),
                ("measured_speedup", num(host.makespan_secs / m.makespan_secs)),
                ("projected_speedup", num(projected)),
                ("cpu_speedup", num(cpu_speedup)),
                ("beta", num(beta)),
                ("cpu_vertices", num(r.vertices[0] as f64)),
            ]));
        }
        fig.series.push(series);
    }

    let md = format!("{}\n{}", table.markdown(), fig.markdown());
    print!("{md}");
    save(
        "fig09_partitioning",
        &md,
        &obj(vec![("host_teps", num(host.teps)), ("rows", arr(rows))]),
    )
    .unwrap();
    eprintln!("fig09_partitioning: done");
}
