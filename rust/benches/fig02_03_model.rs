//! Figures 2 & 3 (paper §3.3): predicted speedup from the performance
//! model. Pure model evaluation — reproduces the paper's curves exactly,
//! since they depend only on Eq. 4.
//!
//! Fig 2 left:  speedup vs α for r_cpu ∈ {0.5, 1, 2, 5} BE/s (β = 5%).
//! Fig 2 right: speedup vs α for β ∈ {5, 10, 20, 40, 100}% (r_cpu = 1).
//! Fig 3:       speedup vs per-edge message volume (α = 60%, r_cpu = 1).

use totem::model::{comm_rate_for_message_bytes, speedup_eq4, ModelParams};
use totem::report::{save, Figure, Series};
use totem::util::json::{arr, obj};

fn alphas() -> Vec<f64> {
    (30..=100).step_by(5).map(|x| x as f64 / 100.0).collect()
}

fn main() {
    let c = 3e9;

    // --- Figure 2 left ------------------------------------------------------
    let mut fig2l = Figure::new(
        "Fig 2 (left): predicted speedup vs alpha, varying r_cpu (beta=5%, c=3 BE/s)",
        "alpha (CPU edge share)",
        "speedup",
    );
    for r_cpu in [0.5e9, 1e9, 2e9, 5e9] {
        let p = ModelParams { r_cpu, r_acc: 2.0 * r_cpu, c };
        let mut s = Series::new(&format!("r_cpu={} BE/s", r_cpu / 1e9));
        for a in alphas() {
            s.push(a, speedup_eq4(a, 0.05, &p));
        }
        fig2l.series.push(s);
    }

    // --- Figure 2 right -----------------------------------------------------
    let mut fig2r = Figure::new(
        "Fig 2 (right): predicted speedup vs alpha, varying beta (r_cpu=1 BE/s, c=3 BE/s)",
        "alpha (CPU edge share)",
        "speedup",
    );
    let p1 = ModelParams { r_cpu: 1e9, r_acc: 2e9, c };
    for beta in [0.05, 0.10, 0.20, 0.40, 1.00] {
        let mut s = Series::new(&format!("beta={:.0}%", beta * 100.0));
        for a in alphas() {
            s.push(a, speedup_eq4(a, beta, &p1));
        }
        fig2r.series.push(s);
    }

    // --- Figure 3 -----------------------------------------------------------
    let mut fig3 = Figure::new(
        "Fig 3: predicted speedup vs per-edge message volume (alpha=60%, r_cpu=1 BE/s)",
        "message bytes per boundary edge",
        "speedup",
    );
    for beta in [0.05, 0.20, 0.40] {
        let mut s = Series::new(&format!("beta={:.0}%", beta * 100.0));
        for msg_bytes in [4.0, 8.0, 12.0, 16.0, 24.0, 32.0] {
            let p = ModelParams {
                r_cpu: 1e9,
                r_acc: 2e9,
                c: comm_rate_for_message_bytes(c, msg_bytes),
            };
            s.push(msg_bytes, speedup_eq4(0.6, beta, &p));
        }
        fig3.series.push(s);
    }

    let md = format!("{}\n{}\n{}", fig2l.markdown(), fig2r.markdown(), fig3.markdown());
    print!("{md}");
    let json = obj(vec![(
        "figures",
        arr(vec![fig2l.to_json(), fig2r.to_json(), fig3.to_json()]),
    )]);
    save("fig02_03_model", &md, &json).expect("write results");

    // paper sanity anchors: with β≤40% the model predicts speedup at α<1;
    // with β=100% slowdown only past α ≈ 0.7 (§3.3).
    assert!(speedup_eq4(0.7, 0.40, &p1) > 1.0);
    assert!(speedup_eq4(0.60, 1.0, &p1) > 1.0);
    assert!(speedup_eq4(0.75, 1.0, &p1) < 1.0);
    eprintln!("fig02_03_model: OK (anchors hold)");
}
