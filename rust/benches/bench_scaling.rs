//! BENCH_scaling — the repo's first perf-trajectory baseline (DESIGN.md
//! §11): TEPS per algorithm × thread count × balance mode on seeded
//! R-MATs, plus the observable intra-partition load-imbalance spread
//! (`Metrics::chunk_spread_secs`).
//!
//! Host-only: needs no AOT artifacts, so it runs anywhere the crate
//! builds. Emits `BENCH_scaling.json` into the working directory (the
//! committed baseline + the CI artifact) and the usual markdown/JSON pair
//! under `results/`.
//!
//! Expectation encoded by the committed baseline: on skewed R-MATs at
//! threads > 1, `edge` and `hub-split` rows meet or beat `vertex` TEPS,
//! because vertex-count chunks hand one worker all the hubs (Fig. 11's
//! imbalance story). Order-sensitive kernels (PageRank push, BC forward
//! σ) run their canonical sequential path regardless of mode, so their
//! rows move only with the pool's dispatch overhead.
//!
//! Flags: --scales 12,13  --threads 1,2,4  --reps 2  --seed 42
//!        --algs bfs,sssp,cc,widest,pagerank,bc  --out BENCH_scaling.json

use totem::engine::{Balance, EngineConfig};
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::report::{fmt_teps, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s, JsonValue};

fn main() {
    let args = Args::from_env().unwrap();
    let reps = args.usize_or("reps", 2).unwrap();
    let seed = args.u64_or("seed", 42).unwrap();
    let scales: Vec<u32> = args
        .f64_list_or("scales", &[12.0, 13.0])
        .unwrap()
        .into_iter()
        .map(|x| x as u32)
        .collect();
    let threads: Vec<usize> = args
        .f64_list_or("threads", &[1.0, 2.0, 4.0])
        .unwrap()
        .into_iter()
        .map(|x| x as usize)
        .collect();
    let algs: Vec<AlgKind> = args
        .str_or("algs", "bfs,sssp,cc,widest,pagerank,bc")
        .split(',')
        .map(|a| AlgKind::parse(a.trim()).unwrap())
        .collect();
    let out_path = args.str_or("out", "BENCH_scaling.json");

    let mut rows: Vec<JsonValue> = Vec::new();
    let mut md = String::new();
    for &alg in &algs {
        for &scale in &scales {
            let g = build_workload(Workload::Rmat(scale), seed, alg);
            let mut t = Table::new(
                &format!("BENCH_scaling: {} on RMAT{scale} (seed {seed})", alg.name()),
                &["threads", "vertex", "edge", "hub-split"],
            );
            for &th in &threads {
                let mut row = vec![th.to_string()];
                for bal in Balance::ALL {
                    let cfg = EngineConfig::host_only(th).with_balance(bal);
                    match measure(&g, RunSpec::new(alg), &cfg, reps) {
                        Ok(m) => {
                            let spread = (0..m.last.metrics.partitions)
                                .map(|p| m.last.metrics.chunk_spread_secs(p))
                                .fold(0.0, f64::max);
                            row.push(fmt_teps(m.teps));
                            rows.push(obj(vec![
                                ("alg", s(alg.name())),
                                ("scale", num(scale as f64)),
                                ("threads", num(th as f64)),
                                ("balance", s(bal.name())),
                                ("teps", num(m.teps)),
                                ("makespan_secs", num(m.makespan_secs)),
                                ("chunk_spread_secs", num(spread)),
                                ("supersteps", num(m.last.supersteps as f64)),
                            ]));
                        }
                        Err(e) => {
                            eprintln!("bench_scaling: {} failed: {e:#}", alg.name());
                            row.push("-".into());
                        }
                    }
                }
                t.row(row);
            }
            md.push_str(&t.markdown());
            md.push('\n');
        }
    }
    print!("{md}");

    let doc = obj(vec![
        ("bench", s("BENCH_scaling")),
        ("workloads", s("paper-parameter R-MAT (a=0.57 b=0.19 c=0.19, avg degree 16, permuted)")),
        ("seed", num(seed as f64)),
        (
            "methodology",
            s("measured: host-only engine runs, mean TEPS over reps after one warmup; \
               teps = traversed_edges / makespan (Eq. 2 accounting)"),
        ),
        ("rows", arr(rows.clone())),
    ]);
    std::fs::write(&out_path, doc.render()).unwrap();
    save("bench_scaling", &md, &obj(vec![("rows", arr(rows))])).unwrap();
    eprintln!("bench_scaling: wrote {out_path}");
}
