//! BENCH_serving — throughput of the concurrent query-serving layer
//! (DESIGN.md §13): queries/second × lane-batch width × admission limit
//! on seeded R-MAT graphs.
//!
//! Host-only and cache-disabled by default, so the batching win is
//! isolated: `--batches 1` is the sequential baseline (every BFS query
//! runs its own traversal), wider settings let the batcher fold queued
//! queries into one bit-parallel multi-source run. The headline number is
//! the `speedup vs batch=1` column — the acceptance target for ISSUE 8 is
//! ≥ 8× at full width on a scale-18 R-MAT (`--scale 18`).
//!
//! The query stream is closed-loop: all queries are submitted up front
//! (rate 0) and the wall clock runs until the last answer, so queries/sec
//! measures server drain rate, not arrival pacing. Sources are sampled
//! with repeats from a seeded xorshift — repeats exercise lane dedup
//! exactly as a real query mix would.
//!
//! Flags: --scale 13  --queries 128  --batches 1,8,64  --inflight 256
//!        --serve-workers 2  --threads 2  --cache 0  --seed 42
//!        --out BENCH_serving.json

use totem::engine::EngineConfig;
use totem::graph::{rmat, CsrGraph, RmatParams};
use totem::report::{save, Table};
use totem::serve::{QueryKind, Server, ServerConfig};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s, JsonValue};

struct Outcome {
    qps: f64,
    wall_secs: f64,
    batches: u64,
    rejected: u64,
    p50_ms: f64,
    p99_ms: f64,
}

fn drive(g: &CsrGraph, cfg: ServerConfig, queries: &[QueryKind]) -> Outcome {
    let srv = Server::start(g.clone(), cfg).unwrap();
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(queries.len());
    for &q in queries {
        match srv.submit(q) {
            Ok(t) => tickets.push(t),
            Err(_) => {} // typed rejection; counted in the report
        }
    }
    let mut answered = 0usize;
    for t in tickets {
        if t.wait().is_ok() {
            answered += 1;
        }
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    let report = srv.shutdown();
    Outcome {
        qps: answered as f64 / wall_secs.max(1e-9),
        wall_secs,
        batches: report.batches,
        rejected: report.rejected,
        p50_ms: report.histogram.quantile_secs(0.50) * 1e3,
        p99_ms: report.histogram.quantile_secs(0.99) * 1e3,
    }
}

fn main() {
    let args = Args::from_env().unwrap();
    let scale = args.usize_or("scale", 13).unwrap() as u32;
    let nqueries = args.usize_or("queries", 128).unwrap();
    let seed = args.u64_or("seed", 42).unwrap();
    let batches: Vec<usize> = args
        .f64_list_or("batches", &[1.0, 8.0, 64.0])
        .unwrap()
        .into_iter()
        .map(|x| x as usize)
        .collect();
    let inflight = args.usize_or("inflight", 256).unwrap();
    let workers = args.usize_or("serve-workers", 2).unwrap();
    let threads = args.usize_or("threads", 2).unwrap();
    let cache = args.usize_or("cache", 0).unwrap();
    let out_path = args.str_or("out", "BENCH_serving.json");

    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(scale, seed)));
    // Seeded closed-loop BFS mix with repeats (lane dedup + realistic
    // hot-source skew).
    let mut x = seed | 1;
    let queries: Vec<QueryKind> = (0..nqueries)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            QueryKind::Bfs { source: (x % g.vertex_count as u64) as u32 }
        })
        .collect();

    eprintln!(
        "bench_serving: RMAT{scale} |V|={} |E|={}, {} queries, {} serve workers x {} threads",
        g.vertex_count,
        g.edge_count(),
        nqueries,
        workers,
        threads
    );

    let mut rows: Vec<JsonValue> = Vec::new();
    let mut t = Table::new(
        &format!("BENCH_serving: {nqueries} BFS queries on RMAT{scale} (seed {seed}, cache {cache})"),
        &["max_batch", "inflight", "queries/s", "batches", "rejected", "p50 ms", "p99 ms", "speedup vs batch=1"],
    );
    let mut baseline_qps: Option<f64> = None;
    for &b in &batches {
        let cfg = ServerConfig {
            workers,
            max_in_flight: inflight,
            max_batch: b,
            cache_capacity: cache,
            ..ServerConfig::new(EngineConfig::host_only(threads))
        };
        let o = drive(&g, cfg, &queries);
        if b == 1 {
            baseline_qps = Some(o.qps);
        }
        let speedup = baseline_qps.map(|base| o.qps / base.max(1e-9));
        t.row(vec![
            b.to_string(),
            inflight.to_string(),
            format!("{:.1}", o.qps),
            o.batches.to_string(),
            o.rejected.to_string(),
            format!("{:.3}", o.p50_ms),
            format!("{:.3}", o.p99_ms),
            speedup.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".into()),
        ]);
        rows.push(obj(vec![
            ("scale", num(scale as f64)),
            ("max_batch", num(b as f64)),
            ("max_inflight", num(inflight as f64)),
            ("serve_workers", num(workers as f64)),
            ("threads", num(threads as f64)),
            ("queries", num(nqueries as f64)),
            ("qps", num(o.qps)),
            ("wall_secs", num(o.wall_secs)),
            ("batches", num(o.batches as f64)),
            ("rejected", num(o.rejected as f64)),
            ("p50_ms", num(o.p50_ms)),
            ("p99_ms", num(o.p99_ms)),
            ("speedup_vs_sequential", num(speedup.unwrap_or(1.0))),
        ]));
    }
    let md = t.markdown();
    print!("{md}");

    let doc = obj(vec![
        ("bench", s("BENCH_serving")),
        ("workloads", s("paper-parameter R-MAT (a=0.57 b=0.19 c=0.19, avg degree 16, permuted)")),
        ("seed", num(seed as f64)),
        (
            "methodology",
            s("measured: closed-loop replay of a seeded BFS query mix against the serving \
               layer, cache disabled; queries/s = answered / wall clock from first submit to \
               last answer; batch=1 is the sequential baseline (one traversal per query), \
               wider max_batch lets the lane batcher fold queued queries into one \
               bit-parallel multi-source run"),
        ),
        ("rows", arr(rows.clone())),
    ]);
    std::fs::write(&out_path, doc.render()).unwrap();
    save("bench_serving", &md, &obj(vec![("rows", arr(rows))])).unwrap();
    eprintln!("bench_serving: wrote {out_path}");
}
