//! Figures 12 & 13 (paper §6.3.2): why HIGH partitioning super-linearly
//! accelerates the CPU side.
//!
//! Fig 13 is exact: the percentage of vertices assigned to the CPU per
//! strategy and α — on a scale-free graph HIGH needs orders of magnitude
//! fewer vertices for the same edge share.
//!
//! Fig 12 uses two proxies for the hardware counters the paper reads
//! (LLC_MISS / LLC_REFS): (i) instrumented state-memory references of the
//! CPU kernels relative to host-only processing, and (ii) the BFS
//! visited-bitmap working-set size relative to a nominal LLC — the paper's
//! own explanation of the miss-rate effect (32MB bitmap vs 40MB LLC).
//!
//! The **placement table** (DESIGN.md §9) goes beyond the proxies: it
//! measures real instrumented state references per intra-partition vertex
//! [`Placement`] on a forced bottom-up BFS, where the transpose probe
//! order — and with it the number of state touches until the first
//! frontier parent — is a direct function of the layout. Locality-aware
//! placements (`degree-desc`, `bfs`) must not reference more state than
//! the raw assignment order on R-MAT workloads.

use totem::engine::{DirectionConfig, EngineConfig};
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::model::locality::{locality_factor, LocalityParams};
use totem::partition::{assign, assignment_stats, Placement, Strategy, ALL_PLACEMENTS};
use totem::report::{save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

/// Nominal LLC for the working-set proxy, scaled to the workload like the
/// paper's 40MB-LLC-vs-32MB-bitmap ratio.
fn nominal_llc_bits(total_vertices: usize) -> f64 {
    // paper: bitmap(|V|) / LLC = 32MB/40MB = 0.8 for the full graph
    total_vertices as f64 / 0.8
}

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let scale = args.usize_or("scale", 14).unwrap() as u32;
    let reps = args.usize_or("reps", 2).unwrap();
    let g = build_workload(Workload::Rmat(scale), 42, AlgKind::Bfs);

    // --- Fig 13: vertex share on the CPU (exact, no execution needed) ------
    let mut t13 = Table::new(
        &format!("Fig 13: % vertices on CPU vs % edges on CPU (RMAT{scale})"),
        &["alpha (edges)", "RAND", "HIGH", "LOW"],
    );
    let mut rows13 = Vec::new();
    for alpha in [0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
        let mut cells = vec![format!("{:.0}%", alpha * 100.0)];
        let mut record = vec![("alpha", num(alpha))];
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let a = assign(&g, strat, &[alpha, 1.0 - alpha], 42);
            let st = assignment_stats(&g, &a, 2);
            let share = st.vertices[0] as f64 / g.vertex_count as f64;
            cells.push(format!("{:.2}%", share * 100.0));
            record.push(match strat {
                Strategy::Rand => ("rand", num(share)),
                Strategy::High => ("high", num(share)),
                Strategy::Low => ("low", num(share)),
            });
        }
        t13.row(cells);
        rows13.push(obj(record));
    }

    // paper-shape anchor: at 80% edges, HIGH's CPU vertex share must be
    // far below LOW's (two orders of magnitude at the paper's RMAT28
    // scale; skew — and hence the gap — grows with scale, so the anchor
    // at bench scale is a conservative 2.5×. At 50% edges the gap is
    // already ≥10× even here, checked in the unit tests).
    let a_high = assignment_stats(&g, &assign(&g, Strategy::High, &[0.8, 0.2], 42), 2);
    let a_low = assignment_stats(&g, &assign(&g, Strategy::Low, &[0.8, 0.2], 42), 2);
    assert!(
        (a_high.vertices[0] as f64) * 2.5 < a_low.vertices[0] as f64,
        "HIGH must place far fewer vertices on the CPU ({} vs {})",
        a_high.vertices[0],
        a_low.vertices[0]
    );

    // --- Fig 12: memory-reference proxies (instrumented runs) --------------
    let mut t12 = Table::new(
        &format!(
            "Fig 12 proxy: CPU memory references and bitmap working set (RMAT{scale}, alpha=0.8, 2S1G)"
        ),
        &[
            "config",
            "mem refs vs 2S",
            "bitmap bits / nominal LLC",
            "cpu verts",
        ],
    );
    let host_cfg = EngineConfig::host_only(1).with_instrument(true);
    let host = measure(&g, RunSpec::new(AlgKind::Bfs), &host_cfg, reps).expect("host");
    let host_refs = (host.last.metrics.mem[0].reads + host.last.metrics.mem[0].writes) as f64;
    let llc = nominal_llc_bits(g.vertex_count);
    t12.row(vec![
        "2S (host only)".into(),
        "100%".into(),
        format!("{:.2}", g.vertex_count as f64 / llc),
        g.vertex_count.to_string(),
    ]);
    let mut rows12 = Vec::new();
    let have_artifacts = artifacts.join("manifest.json").exists();
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        let cfg = if have_artifacts {
            EngineConfig::hybrid(1, 0.8, strat)
                .with_artifacts(&artifacts)
                .with_instrument(true)
        } else {
            EngineConfig::cpu_partitions(&[0.8, 0.2], strat).with_instrument(true)
        };
        let Ok(m) = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, reps) else {
            continue;
        };
        let refs = (m.last.metrics.mem[0].reads + m.last.metrics.mem[0].writes) as f64;
        let bitmap_ratio = m.last.vertices[0] as f64 / llc;
        t12.row(vec![
            format!("2S1G {}", strat.name()),
            format!("{:.0}%", 100.0 * refs / host_refs),
            format!("{bitmap_ratio:.3}"),
            m.last.vertices[0].to_string(),
        ]);
        rows12.push(obj(vec![
            ("strategy", s(strat.name())),
            ("refs_vs_host", num(refs / host_refs)),
            ("bitmap_ratio", num(bitmap_ratio)),
        ]));
    }

    // --- Placement table: measured state references per layout -------------
    // Forced bottom-up BFS (the α/β knobs make every superstep with a
    // non-empty frontier pull): the probe loop walks each unexplored
    // vertex's transpose row until the first frontier parent, so the
    // instrumented reference count depends on the intra-partition order.
    // Host-only keeps the whole graph in one partition — the pure layout
    // effect, no assignment confound.
    let force_pull = DirectionConfig { alpha: 1e12, beta: 1e12 };
    let mut tp = Table::new(
        &format!("Placement: measured BFS state references, forced bottom-up (RMAT{scale}, host-only)"),
        &["placement", "state refs", "vs assign", "pull steps"],
    );
    let mut rows_placement = Vec::new();
    let mut refs_by_placement = Vec::new();
    for placement in ALL_PLACEMENTS {
        let cfg = EngineConfig::host_only(1)
            .with_instrument(true)
            .with_placement(placement)
            .with_direction(force_pull);
        let m = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, reps).expect("placement run");
        let refs = m.last.metrics.mem[0].reads + m.last.metrics.mem[0].writes;
        refs_by_placement.push((placement, refs));
        tp.row(vec![
            placement.name().into(),
            refs.to_string(),
            String::new(), // filled below once the assign row is known
            m.pull_steps.to_string(),
        ]);
        rows_placement.push(obj(vec![
            ("placement", s(placement.name())),
            ("state_refs", num(refs as f64)),
            ("pull_steps", num(m.pull_steps as f64)),
        ]));
    }
    let assign_refs = refs_by_placement
        .iter()
        .find(|(p, _)| *p == Placement::AssignmentOrder)
        .map(|&(_, r)| r)
        .expect("assign placement measured");
    for (row, &(_, refs)) in tp.rows.iter_mut().zip(&refs_by_placement) {
        row[2] = format!("{:.1}%", 100.0 * refs as f64 / assign_refs as f64);
    }
    // Acceptance anchor (ISSUE 4): locality-aware placements reference no
    // more state than the raw assignment order on R-MAT.
    for target in [Placement::DegreeDesc, Placement::BfsOrder] {
        let refs = refs_by_placement.iter().find(|(p, _)| *p == target).unwrap().1;
        assert!(
            refs <= assign_refs,
            "{} must not reference more state than assign ({refs} vs {assign_refs})",
            target.name()
        );
    }

    // Locality cost-model calibration echo (DESIGN.md §9.3): the Fig-12
    // anchor keeps this graph's working set LLC-resident (λ = 1 for any
    // CPU subset of it, by construction of the 0.8 ratio), so show where
    // the ramp engages — the multiples of |V| at which the model starts
    // charging the CPU term.
    let lp = LocalityParams::fig12_reference(g.vertex_count);
    let ramp: Vec<String> = [1.0f64, 1.5, 2.0, 4.0]
        .iter()
        .map(|&k| format!("λ({k}×|V|)={:.2}", locality_factor(k * g.vertex_count as f64, &lp)))
        .collect();
    let ramp_line = format!("Locality model ramp (fig12 anchor): {}\n", ramp.join(", "));

    let md = format!(
        "{}\n{}\n{}\n{ramp_line}",
        t13.markdown(),
        t12.markdown(),
        tp.markdown()
    );
    print!("{md}");
    save(
        "fig12_13_cache",
        &md,
        &obj(vec![
            ("fig13", arr(rows13)),
            ("fig12", arr(rows12)),
            ("placement", arr(rows_placement)),
        ]),
    )
    .unwrap();
    eprintln!("fig12_13_cache: done (HIGH CPU-vertex share + placement locality anchors hold)");
}
