//! Table 4 (paper §9.4): processing times for all five algorithms across
//! framework/hardware configurations on the Twitter workload.
//!
//! The `baseline` module plays the Galois/Ligra role: a clean whole-graph
//! shared-memory implementation with no partitioning machinery. The TOTEM
//! columns run the engine host-only (2S) and hybrid (1S1G / 2S1G / 2S2G).
//! PageRank times one round and BC one source, exactly like the paper's
//! table.

use std::time::Instant;
use totem::baseline;
use totem::engine::EngineConfig;
use totem::graph::{generator, CsrGraph, RmatParams, Workload};
use totem::harness::{measure, AlgKind, RunSpec, ALL_ALGS};
use totem::partition::Strategy;
use totem::report::{fmt_secs, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn baseline_secs(alg: AlgKind, g: &CsrGraph, reps: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        match alg {
            AlgKind::Bfs => {
                let _ = baseline::bfs(g, 1);
            }
            AlgKind::Pagerank => {
                let _ = baseline::pagerank(g, 1);
            }
            AlgKind::Sssp => {
                let _ = baseline::sssp(g, 1);
            }
            AlgKind::Bc => {
                let _ = baseline::bc(g, 1);
            }
            AlgKind::Cc => {
                let _ = baseline::cc(g);
            }
            AlgKind::Widest => {
                let _ = baseline::widest(g, 1);
            }
            AlgKind::Triangles => {
                let _ = baseline::triangles(g);
            }
            AlgKind::Kcore => {
                let _ = baseline::kcore(g);
            }
            AlgKind::Labelprop => {
                let _ = baseline::labelprop(g, 1);
            }
            AlgKind::Ppr => {
                let _ = baseline::ppr(g, 1, 1);
            }
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let have_accel = artifacts.join("manifest.json").exists();
    let reps = args.usize_or("reps", 2).unwrap();
    let alpha = args.f64_or("alpha", 0.7).unwrap();
    let mut el = if args.has("full") {
        Workload::TwitterProxy.generate(7)
    } else {
        generator::rmat(&RmatParams {
            scale: 14,
            avg_degree: 36,
            a: 0.60,
            b: 0.19,
            c: 0.19,
            permute: true,
            seed: 7,
        })
    };
    generator::with_random_weights(&mut el, 64, 9);
    let g = CsrGraph::from_edge_list(&el);
    eprintln!("Twitter proxy: |V|={} |E|={}", g.vertex_count, g.edge_count());

    let mut t = Table::new(
        "Table 4: processing times (Twitter proxy; PageRank=1 round, BC=1 source)",
        &["algorithm", "2S-Baseline", "2S-TOTEM", "1S1G", "2S1G", "2S2G"],
    );
    let mut rows = Vec::new();
    for alg in ALL_ALGS {
        let spec = RunSpec::new(alg).with_source(1).with_rounds(1);
        let base = baseline_secs(alg, &g, reps);
        let host = measure(&g, spec, &EngineConfig::host_only(1), reps)
            .map(|m| m.makespan_secs)
            .unwrap_or(f64::NAN);
        let mut cells = vec![alg.name().to_string(), fmt_secs(base), fmt_secs(host)];
        let mut jrow = vec![
            ("alg", s(alg.name())),
            ("baseline", num(base)),
            ("totem_2s", num(host)),
        ];
        for hw in ["1S1G", "2S1G", "2S2G"] {
            if !have_accel {
                cells.push("-".into());
                continue;
            }
            let cfg = EngineConfig::from_notation(hw, alpha, Strategy::High, 1)
                .unwrap()
                .with_artifacts(&artifacts);
            match measure(&g, spec, &cfg, reps) {
                Ok(m) => {
                    cells.push(fmt_secs(m.makespan_secs));
                    jrow.push(match hw {
                        "1S1G" => ("hyb_1s1g", num(m.makespan_secs)),
                        "2S1G" => ("hyb_2s1g", num(m.makespan_secs)),
                        _ => ("hyb_2s2g", num(m.makespan_secs)),
                    });
                }
                Err(_) => cells.push("-".into()),
            }
        }
        t.row(cells);
        rows.push(obj(jrow));
    }
    let md = t.markdown();
    print!("{md}");
    save("table4_frameworks", &md, &obj(vec![("rows", arr(rows))])).unwrap();
    eprintln!("table4_frameworks: done");
}
