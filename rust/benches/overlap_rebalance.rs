//! Pipelined-executor and dynamic-α benchmarks (DESIGN.md §4–5).
//!
//! On a skewed R-MAT workload with a deliberately imbalanced launch
//! split, measures:
//!
//! 1. **Overlap**: synchronous vs pipelined makespan for the same
//!    partitioning — the pipelined engine hides pairwise exchanges behind
//!    the bottleneck element's compute; the realized overlap factor is
//!    `Metrics::overlap_factor`.
//! 2. **Re-balancing**: the dynamic α controller migrating low-degree
//!    bands off the overloaded element, on top of either executor.
//!
//! Pass criterion (ISSUE 2): pipelined makespan <= synchronous makespan.
//!
//! Caveat (DESIGN.md §2): per-partition compute is wall-clock measured
//! inside each compute thread. On a single hardware core the pipelined
//! executor's threads timeshare, inflating per-partition measurements and
//! with them the reported makespan — on such machines the comparison
//! prints WARN rather than signalling a real regression. Any ≥2-core
//! machine (including CI runners) measures the overlap faithfully.

use totem::engine::{EngineConfig, RebalanceConfig};
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};

fn main() {
    let args = Args::from_env().unwrap();
    let scale = args.usize_or("scale", 13).unwrap() as u32;
    let reps = args.usize_or("reps", 3).unwrap();
    // Skewed on purpose: element 0 gets the hubs and 70% of the edges,
    // the other two elements split the rest. Three elements matter: with
    // two, every exchange needs both endpoints, so the last finisher
    // unblocks everything and nothing can hide; with three, the two fast
    // elements exchange while the overloaded one still computes.
    let shares = [0.70, 0.15, 0.15];
    let rebalance = RebalanceConfig {
        imbalance_threshold: 0.10,
        patience: 1,
        migration_band: 0.15,
        max_migrations: 6,
    };

    let mut md = String::new();
    let mut json = Vec::new();
    let mut all_pass = true;

    for alg in [AlgKind::Bfs, AlgKind::Pagerank, AlgKind::Sssp] {
        let g = build_workload(Workload::Rmat(scale), 42, alg);
        let spec = RunSpec::new(alg).with_rounds(5);
        let mut t = Table::new(
            &format!(
                "{}: overlap + rebalancing on RMAT{scale}, 3 CPU elements, shares={shares:?}",
                alg.name()
            ),
            &["engine", "makespan", "comm", "overlap", "migrations", "vs sync"],
        );

        let base = EngineConfig::cpu_partitions(&shares, Strategy::High);
        let engines: Vec<(&str, EngineConfig)> = vec![
            ("synchronous", base.clone()),
            ("pipelined", base.clone().pipelined()),
            ("sync+rebalance", base.clone().with_rebalance(rebalance)),
            (
                "pipelined+rebalance",
                base.clone().pipelined().with_rebalance(rebalance),
            ),
        ];

        let mut sync_makespan = f64::NAN;
        for (name, cfg) in engines {
            match measure(&g, spec, &cfg, reps) {
                Ok(m) => {
                    if name == "synchronous" {
                        sync_makespan = m.makespan_secs;
                    }
                    let ratio = sync_makespan / m.makespan_secs;
                    if name == "pipelined" && m.makespan_secs > sync_makespan * 1.02 {
                        all_pass = false;
                    }
                    t.row(vec![
                        name.into(),
                        fmt_secs(m.makespan_secs),
                        fmt_secs(m.comm_secs),
                        format!("{:.1}%", 100.0 * m.overlap_factor),
                        m.migrations.to_string(),
                        format!("{ratio:.2}x"),
                    ]);
                    json.push(obj(vec![
                        ("alg", s(alg.name())),
                        ("engine", s(name)),
                        ("makespan", num(m.makespan_secs)),
                        ("comm", num(m.comm_secs)),
                        ("overlap_factor", num(m.overlap_factor)),
                        ("migrations", num(m.migrations as f64)),
                    ]));
                }
                Err(e) => {
                    all_pass = false;
                    t.row(vec![
                        name.into(),
                        format!("error: {e:#}"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                }
            }
        }
        md.push_str(&t.markdown());
        md.push('\n');
    }

    let verdict = if all_pass {
        "PASS: pipelined makespan <= synchronous makespan on every algorithm\n"
    } else {
        "WARN: pipelined makespan exceeded synchronous makespan (noise or regression)\n"
    };
    md.push_str(verdict);

    print!("{md}");
    save(
        "overlap_rebalance",
        &md,
        &obj(vec![("entries", arr(json)), ("pass", num(all_pass as u8 as f64))]),
    )
    .unwrap();
    eprintln!("overlap_rebalance: done ({})", if all_pass { "pass" } else { "warn" });
}
