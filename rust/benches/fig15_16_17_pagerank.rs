//! Figures 15, 16, 17 (paper §7.1): PageRank on the UK-WEB proxy.
//!
//! - Fig 15: traversal rate per strategy for one and two accelerators,
//!   with host-only as reference; LOW can offload the most edges (fewest
//!   accelerator vertices per edge), HIGH gives the fastest CPU side.
//! - Fig 16: execution-time breakdown (computation dominates, comm small).
//! - Fig 17: CPU read vs write memory accesses per strategy relative to
//!   host-only — HIGH slashes writes (∝ |V_cpu|) while reads (∝ |E_cpu|)
//!   stay put.

use totem::engine::EngineConfig;
use totem::graph::{rmat, CsrGraph, RmatParams, Workload};
use totem::harness::{measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, fmt_teps, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("fig15_16_17_pagerank: SKIP (run `make artifacts`)");
        return;
    }
    let reps = args.usize_or("reps", 2).unwrap();
    let rounds = args.usize_or("rounds", 5).unwrap();
    // web-crawl workload: the full proxy with --full, a scale-15 web-shaped
    // graph otherwise (same skew parameters, 1/8 size) to keep bench time low.
    let g: CsrGraph = if args.has("full") {
        Workload::UkWebProxy.build(42)
    } else {
        CsrGraph::from_edge_list(&rmat(&RmatParams {
            scale: 15,
            avg_degree: 35,
            a: 0.62,
            b: 0.19,
            c: 0.17,
            permute: true,
            seed: 42,
        }))
    };
    eprintln!("workload: |V|={} |E|={}", g.vertex_count, g.edge_count());
    let spec = RunSpec::new(AlgKind::Pagerank).with_rounds(rounds);

    let host_cfg = EngineConfig::host_only(1).with_instrument(true);
    let host = measure(&g, spec, &host_cfg, reps).expect("host");
    let host_reads = host.last.metrics.mem[0].reads as f64;
    let host_writes = host.last.metrics.mem[0].writes as f64;

    let mut t15 = Table::new(
        "Fig 15: PageRank rate by strategy (UK-WEB proxy)",
        &["config", "strategy", "rate", "vs host", "accel verts", "accel edges"],
    );
    let mut t16 = Table::new(
        "Fig 16: PageRank breakdown",
        &["config", "strategy", "total", "cpu", "accel", "comm", "comm %"],
    );
    let mut t17 = Table::new(
        "Fig 17: CPU memory accesses vs host-only",
        &["strategy", "reads %", "writes %", "cpu verts"],
    );
    t15.row(vec![
        "2S".into(),
        "-".into(),
        fmt_teps(host.teps),
        "1.00x".into(),
        "-".into(),
        "-".into(),
    ]);
    let mut rows = Vec::new();
    for accels in [1usize, 2] {
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let cfg = EngineConfig::hybrid(accels, 0.7, strat)
                .with_artifacts(&artifacts)
                .with_instrument(true);
            let m = match measure(&g, spec, &cfg, reps) {
                Ok(m) => m,
                Err(_) => {
                    // Fig 15's "missing bars": partition does not fit
                    t15.row(vec![
                        format!("2S{accels}G"),
                        strat.name().into(),
                        "does not fit".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                }
            };
            let r = &m.last;
            let acc: f64 = (1..=accels).map(|p| r.metrics.partition_compute_secs(p)).sum();
            t15.row(vec![
                format!("2S{accels}G"),
                strat.name().into(),
                fmt_teps(m.teps),
                format!("{:.2}x", host.makespan_secs / m.makespan_secs),
                r.vertices[1..].iter().sum::<usize>().to_string(),
                r.footprints[1..].iter().map(|f| f.edges).sum::<usize>().to_string(),
            ]);
            t16.row(vec![
                format!("2S{accels}G"),
                strat.name().into(),
                fmt_secs(m.makespan_secs),
                fmt_secs(r.metrics.partition_compute_secs(0)),
                fmt_secs(acc),
                fmt_secs(m.comm_secs),
                format!("{:.1}%", 100.0 * m.comm_secs / m.makespan_secs),
            ]);
            if accels == 1 {
                t17.row(vec![
                    strat.name().into(),
                    format!("{:.0}%", 100.0 * r.metrics.mem[0].reads as f64 / host_reads),
                    format!("{:.0}%", 100.0 * r.metrics.mem[0].writes as f64 / host_writes),
                    r.vertices[0].to_string(),
                ]);
            }
            rows.push(obj(vec![
                ("config", s(&format!("2S{accels}G"))),
                ("strategy", s(strat.name())),
                ("teps", num(m.teps)),
                ("reads", num(r.metrics.mem[0].reads as f64)),
                ("writes", num(r.metrics.mem[0].writes as f64)),
                ("cpu_vertices", num(r.vertices[0] as f64)),
            ]));
        }
    }

    let md = format!("{}\n{}\n{}", t15.markdown(), t16.markdown(), t17.markdown());
    print!("{md}");
    save(
        "fig15_16_17_pagerank",
        &md,
        &obj(vec![
            ("host_reads", num(host_reads)),
            ("host_writes", num(host_writes)),
            ("rows", arr(rows)),
        ]),
    )
    .unwrap();
    eprintln!("fig15_16_17_pagerank: done");
}
