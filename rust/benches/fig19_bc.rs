//! Figure 19 (paper §7.2): Betweenness Centrality on the Twitter proxy —
//! traversal rate per strategy and α (left), execution breakdown at the
//! maximum offloadable partition (right).
//!
//! Paper shape: HIGH wins at a fixed α; LOW can offload more edges
//! (BC keeps 5 per-vertex state arrays, so accelerator capacity is
//! vertex-bound and LOW's few-vertex accelerator partitions fit more
//! edges), which in the paper flips the overall winner to LOW.

use totem::engine::EngineConfig;
use totem::graph::{rmat, CsrGraph, RmatParams, Workload};
use totem::harness::{measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, fmt_teps, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("fig19_bc: SKIP (run `make artifacts`)");
        return;
    }
    let reps = args.usize_or("reps", 2).unwrap();
    let g: CsrGraph = if args.has("full") {
        Workload::TwitterProxy.build(7)
    } else {
        CsrGraph::from_edge_list(&rmat(&RmatParams {
            scale: 14,
            avg_degree: 36,
            a: 0.60,
            b: 0.19,
            c: 0.19,
            permute: true,
            seed: 7,
        }))
    };
    eprintln!("workload: |V|={} |E|={}", g.vertex_count, g.edge_count());
    let spec = RunSpec::new(AlgKind::Bc).with_source(1);

    let host = measure(&g, spec, &EngineConfig::host_only(1), reps).expect("host");

    let mut t_rate = Table::new(
        "Fig 19 (left): BC rate by strategy and alpha (2S1G)",
        &["strategy", "alpha", "rate", "vs host", "max offload?"],
    );
    let mut t_break = Table::new(
        "Fig 19 (right): BC breakdown at max offload",
        &["strategy", "max alpha fits", "total", "cpu", "accel", "comm"],
    );
    let mut rows = Vec::new();
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        // find the maximum offload (minimum alpha) that still fits, then
        // report the sweep — the paper's "LOW offloads 20% more" effect.
        let mut min_fitting_alpha = None;
        for &alpha in &[0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
            let cfg = EngineConfig::hybrid(1, alpha, strat).with_artifacts(&artifacts);
            match measure(&g, spec, &cfg, reps) {
                Ok(m) => {
                    if min_fitting_alpha.is_none() {
                        min_fitting_alpha = Some(alpha);
                        let r = &m.last;
                        t_break.row(vec![
                            strat.name().into(),
                            format!("{alpha:.1}"),
                            fmt_secs(m.makespan_secs),
                            fmt_secs(r.metrics.partition_compute_secs(0)),
                            fmt_secs(r.metrics.partition_compute_secs(1)),
                            fmt_secs(m.comm_secs),
                        ]);
                    }
                    t_rate.row(vec![
                        strat.name().into(),
                        format!("{alpha:.1}"),
                        fmt_teps(m.teps),
                        format!("{:.2}x", host.makespan_secs / m.makespan_secs),
                        if Some(alpha) == min_fitting_alpha { "max".into() } else { "".into() },
                    ]);
                    rows.push(obj(vec![
                        ("strategy", s(strat.name())),
                        ("alpha", num(alpha)),
                        ("teps", num(m.teps)),
                        (
                            "speedup",
                            num(host.makespan_secs / m.makespan_secs),
                        ),
                    ]));
                }
                Err(_) => {
                    t_rate.row(vec![
                        strat.name().into(),
                        format!("{alpha:.1}"),
                        "does not fit".into(),
                        "-".into(),
                        "".into(),
                    ]);
                }
            }
        }
    }

    let md = format!(
        "host-only BC rate: {}\n\n{}\n{}",
        fmt_teps(host.teps),
        t_rate.markdown(),
        t_break.markdown()
    );
    print!("{md}");
    save(
        "fig19_bc",
        &md,
        &obj(vec![("host_teps", num(host.teps)), ("rows", arr(rows))]),
    )
    .unwrap();
    eprintln!("fig19_bc: done");
}
