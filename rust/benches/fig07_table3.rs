//! Figure 7 + Table 3 (paper §5.1): model-predicted vs TOTEM-achieved
//! speedup while varying α, for all four algorithms; Pearson correlation
//! and average error per workload.
//!
//! The model parameters are calibrated on this testbed (paper §3.3: r_cpu
//! from the CPU-only run, c from measured channel rate) — the paper's
//! claim under test is that a two-parameter linear model *tracks* the
//! achieved hybrid performance (correlation ≈ 0.9+), not the absolute
//! numbers.

use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::engine::EngineConfig;
use totem::model::{calibrate, speedup};
use totem::partition::Strategy;
use totem::report::{save, Figure, Series, Table};
use totem::stats;
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("fig07_table3: SKIP (run `make artifacts`)");
        return;
    }
    let reps = args.usize_or("reps", 2).unwrap();
    let scales: Vec<u32> = args
        .f64_list_or("scales", &[13.0, 14.0, 15.0])
        .unwrap()
        .into_iter()
        .map(|x| x as u32)
        .collect();
    let algs = [AlgKind::Bfs, AlgKind::Pagerank, AlgKind::Bc, AlgKind::Sssp];
    let alphas = args
        .f64_list_or("alphas", &[0.5, 0.6, 0.7, 0.8, 0.9])
        .unwrap();
    let accel_counts: Vec<usize> = if args.has("two-accels") { vec![1, 2] } else { vec![1] };

    let mut table3 = Table::new(
        "Table 3: model accuracy (correlation + avg error)",
        &["algorithm", "workload", "correlation", "avg err"],
    );
    let mut fig_json = Vec::new();
    let mut fig7: Option<Figure> = None;

    for alg in algs {
        for &scale in &scales {
            let g = build_workload(Workload::Rmat(scale), 42, alg);
            // calibrate on this workload (host run + hybrid probe)
            let cal = match calibrate_alg(&g, alg, &artifacts) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("calibrate {} RMAT{scale}: {e:#}", alg.name());
                    continue;
                }
            };
            let mut predicted = Vec::new();
            let mut achieved = Vec::new();
            let mut series_pred = Series::new(&format!("{}-model", alg.name()));
            let mut series_ach = Series::new(&format!("{}-achieved", alg.name()));
            for &accels in &accel_counts {
                for &alpha in &alphas {
                    let cfg = EngineConfig::hybrid(accels, alpha, Strategy::Rand)
                        .with_artifacts(&artifacts);
                    let m = match measure(&g, RunSpec::new(alg), &cfg, reps) {
                        Ok(m) => m,
                        Err(_) => continue, // does not fit the accelerator
                    };
                    let r = &m.last;
                    let beta = calibrate::beta_of(r, g.edge_count());
                    let pred = speedup(r.shares[0], beta, &cal.params);
                    let ach = cal.host_secs / m.makespan_secs;
                    predicted.push(pred);
                    achieved.push(ach);
                    if accels == 1 {
                        series_pred.push(alpha, pred);
                        series_ach.push(alpha, ach);
                    }
                }
            }
            if predicted.len() < 2 {
                continue;
            }
            let corr = stats::pearson(&predicted, &achieved);
            let err = stats::avg_error_pct(&predicted, &achieved);
            table3.row(vec![
                alg.name().to_string(),
                format!("RMAT{scale}"),
                format!("{corr:.2}"),
                format!("{err:+.0}%"),
            ]);
            fig_json.push(obj(vec![
                ("alg", s(alg.name())),
                ("workload", s(&format!("RMAT{scale}"))),
                ("correlation", num(corr)),
                ("avg_err_pct", num(err)),
                ("predicted", arr(predicted.iter().map(|&x| num(x)).collect())),
                ("achieved", arr(achieved.iter().map(|&x| num(x)).collect())),
            ]));
            // figure uses the middle scale
            if scale == scales[scales.len() / 2] {
                let f = fig7.get_or_insert_with(|| {
                    Figure::new(
                        &format!("Fig 7: predicted (model) vs achieved speedup, RMAT{scale} 2S1G"),
                        "alpha",
                        "speedup vs host-only",
                    )
                });
                f.series.push(series_pred);
                f.series.push(series_ach);
            }
        }
    }

    let mut md = table3.markdown();
    if let Some(f) = &fig7 {
        md.push('\n');
        md.push_str(&f.markdown());
    }
    print!("{md}");
    save(
        "fig07_table3",
        &md,
        &obj(vec![("entries", arr(fig_json))]),
    )
    .unwrap();
    eprintln!("fig07_table3: done");
}

fn calibrate_alg(
    g: &totem::graph::CsrGraph,
    alg: AlgKind,
    artifacts: &std::path::Path,
) -> anyhow::Result<calibrate::Calibration> {
    use totem::alg::{
        bc::Bc, bfs::Bfs, cc::Cc, kcore::KCore, labelprop::LabelProp, pagerank::Pagerank,
        ppr::Ppr, sssp::Sssp, triangles::Triangles, widest::Widest,
    };
    // same source policy as the harness sweep (max-degree hub)
    let src = totem::harness::resolve_source(g, &RunSpec::new(alg));
    match alg {
        AlgKind::Bfs => calibrate::calibrate_with(
            g, &mut Bfs::new(src), &mut Bfs::new(src), artifacts, 0.7, Strategy::Rand),
        AlgKind::Pagerank => calibrate::calibrate_with(
            g,
            &mut Pagerank::new(5),
            &mut Pagerank::new(5),
            artifacts,
            0.7,
            Strategy::Rand,
        ),
        AlgKind::Sssp => calibrate::calibrate_with(
            g, &mut Sssp::new(src), &mut Sssp::new(src), artifacts, 0.7, Strategy::Rand),
        AlgKind::Bc => calibrate::calibrate_with(
            g, &mut Bc::new(src), &mut Bc::new(src), artifacts, 0.7, Strategy::Rand),
        AlgKind::Cc => calibrate::calibrate_with(
            g, &mut Cc::new(), &mut Cc::new(), artifacts, 0.7, Strategy::Rand),
        AlgKind::Widest => calibrate::calibrate_with(
            g, &mut Widest::new(src), &mut Widest::new(src), artifacts, 0.7, Strategy::Rand),
        AlgKind::Triangles => calibrate::calibrate_with(
            g, &mut Triangles::new(), &mut Triangles::new(), artifacts, 0.7, Strategy::Rand),
        AlgKind::Kcore => calibrate::calibrate_with(
            g, &mut KCore::new(), &mut KCore::new(), artifacts, 0.7, Strategy::Rand),
        AlgKind::Labelprop => calibrate::calibrate_with(
            g,
            &mut LabelProp::new(5),
            &mut LabelProp::new(5),
            artifacts,
            0.7,
            Strategy::Rand,
        ),
        AlgKind::Ppr => calibrate::calibrate_with(
            g, &mut Ppr::new(src, 5), &mut Ppr::new(src, 5), artifacts, 0.7, Strategy::Rand),
    }
}
