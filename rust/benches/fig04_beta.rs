//! Figure 4 (paper §3.4): ratio of edges that cross partitions (β) with
//! and without message reduction, for two- and three-way random
//! partitioning, on skewed (Twitter/UK-WEB proxies, RMAT) and uniform
//! (Erdős–Rényi) workloads.
//!
//! Paper shape to reproduce: reduction collapses β to <5% on all skewed
//! graphs; the uniform graph is the worst case (reduction barely helps).

use totem::graph::Workload;
use totem::partition::{PartitionedGraph, Strategy};
use totem::report::{save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};

fn main() {
    let args = Args::from_env().unwrap();
    let seed = args.u64_or("seed", 42).unwrap();
    let workloads = if args.has("full") {
        vec![
            Workload::TwitterProxy,
            Workload::UkWebProxy,
            Workload::Rmat(16),
            Workload::Uniform(16),
        ]
    } else {
        vec![
            Workload::TwitterProxy,
            Workload::UkWebProxy,
            Workload::Rmat(14),
            Workload::Uniform(14),
        ]
    };

    let mut table = Table::new(
        "Fig 4: beta with/without reduction (RAND partitioning)",
        &["workload", "parts", "beta raw", "beta reduced", "reduction factor"],
    );
    let mut rows_json = Vec::new();
    for w in &workloads {
        let g = w.build(seed);
        for parts in [2usize, 3] {
            let shares = vec![1.0 / parts as f64; parts];
            let pg = PartitionedGraph::partition(&g, Strategy::Rand, &shares, seed);
            let b = pg.beta_stats();
            table.row(vec![
                w.name(),
                format!("{parts}-way"),
                format!("{:.1}%", 100.0 * b.beta_raw()),
                format!("{:.2}%", 100.0 * b.beta_reduced()),
                format!("{:.1}x", b.beta_raw() / b.beta_reduced().max(1e-12)),
            ]);
            rows_json.push(obj(vec![
                ("workload", s(&w.name())),
                ("parts", num(parts as f64)),
                ("beta_raw", num(b.beta_raw())),
                ("beta_reduced", num(b.beta_reduced())),
            ]));

            // Paper-shape assertions. Raw β for k-way random partitioning
            // is (k-1)/k; with reduction, messages collapse to ~one per
            // unique remote neighbor: at degree d the uniform graph floors
            // at ≈ 1/d (its "worst case" bar), while skewed graphs go
            // lower because hub targets absorb many boundary edges.
            // Skew deepens with scale — the proxies (deg 36, scale 17/18)
            // show the paper's <5%; RMAT at bench scale is asserted
            // relative to the uniform floor.
            let expected_raw = (parts as f64 - 1.0) / parts as f64;
            assert!(
                (b.beta_raw() - expected_raw).abs() < 0.03,
                "{}: raw beta {:.3} should be ≈ {expected_raw:.2}",
                w.name(),
                b.beta_raw()
            );
            match w {
                Workload::TwitterProxy | Workload::UkWebProxy => assert!(
                    b.beta_reduced() < 0.05,
                    "{}: reduced beta {:.3} should be < 5%",
                    w.name(),
                    b.beta_reduced()
                ),
                _ => assert!(
                    b.beta_reduced() < 0.15,
                    "{}: reduced beta {:.3} unexpectedly high",
                    w.name(),
                    b.beta_reduced()
                ),
            }
        }
    }
    let md = table.markdown();
    print!("{md}");
    save("fig04_beta", &md, &obj(vec![("rows", arr(rows_json))])).unwrap();
    eprintln!("fig04_beta: OK (skewed graphs reduce below 5%, uniform stays high)");
}
