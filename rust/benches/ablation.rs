//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Pallas vs plain-XLA lowering** — the `bfs` step lowered through
//!    the blocked Pallas scatter kernel vs the `bfs_jnp` variant (straight
//!    `jnp .at[].min`): measures what the explicit HBM↔VMEM tiling
//!    schedule costs/buys on this backend.
//! 2. **Direction-optimized BFS** (paper §10) — top-down vs the
//!    Beamer-style switching traversal on the host.
//! 3. **Message reduction off vs on** — β raw vs reduced converted to
//!    transfer volume (what Fig 4 implies for bytes on the wire).

use std::time::Instant;
use totem::baseline;
use totem::engine::EngineConfig;
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::partition::{PartitionedGraph, Strategy};
use totem::report::{fmt_secs, save, Table};
use totem::util::args::Args;
use totem::util::json::{num, obj};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let scale = args.usize_or("scale", 14).unwrap() as u32;
    let reps = args.usize_or("reps", 3).unwrap();
    let g = build_workload(Workload::Rmat(scale), 42, AlgKind::Bfs);
    let mut md = String::new();
    let mut json = Vec::new();

    // --- 1. pallas vs jnp step program -------------------------------------
    if artifacts.join("manifest.json").exists() {
        let mut t = Table::new(
            "Ablation 1: Pallas-blocked vs plain-XLA BFS step (2S1G, alpha=0.7)",
            &["program", "makespan", "accel compute"],
        );
        for (label, prog) in [("pallas (bfs)", false), ("jnp (bfs_jnp)", true)] {
            let cfg = EngineConfig::hybrid(1, 0.7, Strategy::High).with_artifacts(&artifacts);
            let res = if prog {
                // run via a thin adapter algorithm that requests bfs_jnp
                measure_jnp(&g, &cfg, reps)
            } else {
                measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, reps)
                    .map(|m| (m.makespan_secs, m.last.metrics.partition_compute_secs(1)))
            };
            match res {
                Ok((mk, acc)) => {
                    t.row(vec![label.into(), fmt_secs(mk), fmt_secs(acc)]);
                    json.push(obj(vec![
                        (if prog { "jnp_makespan" } else { "pallas_makespan" }, num(mk)),
                        (if prog { "jnp_accel" } else { "pallas_accel" }, num(acc)),
                    ]));
                }
                Err(e) => t.row(vec![label.into(), format!("error: {e:#}"), "-".into()]),
            }
        }
        md.push_str(&t.markdown());
        md.push('\n');
    } else {
        eprintln!("ablation 1: SKIP (no artifacts)");
    }

    // --- 2. direction-optimized BFS -----------------------------------------
    {
        let mut t = Table::new(
            "Ablation 2: top-down vs direction-optimized BFS (host, whole graph)",
            &["variant", "time", "speedup"],
        );
        let time = |f: &dyn Fn() -> Vec<i32>| {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t0 = Instant::now();
                let _ = f();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let td = time(&|| baseline::bfs(&g, 1));
        let do_ = time(&|| baseline::bfs_direction_optimized(&g, 1, 0.05));
        t.row(vec!["top-down".into(), fmt_secs(td), "1.00x".into()]);
        t.row(vec![
            "direction-optimized".into(),
            fmt_secs(do_),
            format!("{:.2}x", td / do_),
        ]);
        json.push(obj(vec![("topdown", num(td)), ("diropt", num(do_))]));
        md.push_str(&t.markdown());
        md.push('\n');
    }

    // --- 3. reduction on/off transfer volume --------------------------------
    {
        let mut t = Table::new(
            "Ablation 3: message reduction impact on transfer volume (2-way RAND)",
            &["workload", "bytes/step w/o reduction", "bytes/step with", "saved"],
        );
        for w in [Workload::Rmat(scale), Workload::Uniform(scale)] {
            let gg = w.build(42);
            let pg = PartitionedGraph::partition(&gg, Strategy::Rand, &[0.5, 0.5], 42);
            let b = pg.beta_stats();
            let raw = 4 * b.boundary_edges;
            let red = 4 * b.reduced_messages;
            t.row(vec![
                w.name(),
                totem::util::fmt_bytes(raw),
                totem::util::fmt_bytes(red),
                format!("{:.1}x", raw as f64 / red.max(1) as f64),
            ]);
        }
        md.push_str(&t.markdown());
    }

    print!("{md}");
    save("ablation", &md, &obj(vec![("entries", totem::util::json::arr(json))])).unwrap();
    eprintln!("ablation: done");
}

/// Run BFS through the `bfs_jnp` ablation program: a BFS clone whose
/// ProgramSpec names the plain-XLA lowering.
fn measure_jnp(
    g: &totem::graph::CsrGraph,
    cfg: &EngineConfig,
    reps: usize,
) -> anyhow::Result<(f64, f64)> {
    use totem::alg::{
        AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx,
    };
    use totem::engine::state::{AlgState, CommOp};
    use totem::partition::{Partition, PartitionedGraph};

    struct BfsJnp(totem::alg::bfs::Bfs);
    impl Algorithm for BfsJnp {
        fn spec(&self) -> AlgSpec {
            AlgSpec { name: "bfs", ..self.0.spec() }
        }
        fn init_state(&mut self, pg: &PartitionedGraph, part: &Partition) -> AlgState {
            self.0.init_state(pg, part)
        }
        fn channels(&self, cycle: usize) -> Vec<CommOp> {
            self.0.channels(cycle)
        }
        fn program(&self, _cycle: usize) -> ProgramSpec {
            ProgramSpec {
                name: "bfs_jnp",
                arrays: vec![0],
                pads: vec![Pad::I32(totem::alg::INF_I32)],
                aux: vec![],
                needs_weights: false,
                n_si32: 1,
                n_sf32: 0,
                orientation: EdgeOrientation::Forward,
            }
        }
        fn scalars_i32(&self, ctx: &StepCtx) -> Vec<i32> {
            self.0.scalars_i32(ctx)
        }
        fn compute_cpu(&self, part: &Partition, st: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
            self.0.compute_cpu(part, st, ctx)
        }
    }
    let mut best = (f64::INFINITY, 0.0);
    let mut alg = BfsJnp(totem::alg::bfs::Bfs::new(0));
    let _ = totem::engine::run(g, &mut alg, cfg)?; // warmup
    for _ in 0..reps {
        let mut alg = BfsJnp(totem::alg::bfs::Bfs::new(0));
        let r = totem::engine::run(g, &mut alg, cfg)?;
        let mk = r.makespan_secs();
        if mk < best.0 {
            best = (mk, r.metrics.partition_compute_secs(1));
        }
    }
    Ok(best)
}
