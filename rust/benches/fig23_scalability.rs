//! Figure 23 (paper §8): scalability across graph sizes and hardware
//! configurations — BFS, PageRank, BC, SSSP × RMAT sizes × {1S, 2S, 1S1G,
//! 2S1G, 2S2G}, reporting traversal rates.
//!
//! `xS` socket scaling is thread-count only (single core here; the paper's
//! 2S≈2×1S effect is not observable — noted in EXPERIMENTS.md). The
//! accelerator columns exercise the real PJRT element; partitioning uses
//! the per-algorithm best strategy as in the paper ("the graph is
//! partitioned to obtain best performance").

use totem::engine::EngineConfig;
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_teps, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("fig23_scalability: SKIP (run `make artifacts`)");
        return;
    }
    let reps = args.usize_or("reps", 2).unwrap();
    let scales: Vec<u32> = args
        .f64_list_or("scales", &[12.0, 13.0, 14.0, 15.0])
        .unwrap()
        .into_iter()
        .map(|x| x as u32)
        .collect();
    let alpha = args.f64_or("alpha", 0.75).unwrap();
    let configs = ["1S", "2S", "1S1G", "2S1G", "2S2G"];

    let mut all = Vec::new();
    let mut md = String::new();
    for alg in [AlgKind::Bfs, AlgKind::Pagerank, AlgKind::Bc, AlgKind::Sssp] {
        // per-paper: best strategy per algorithm (HIGH for BFS/PR/SSSP,
        // LOW for BC at max offload; HIGH used everywhere for uniformity
        // of the sweep, as HIGH also wins BC at fixed alpha).
        let strategy = Strategy::High;
        let mut t = Table::new(
            &format!("Fig 23: {} rate by config and size", alg.name()),
            &["workload", "1S", "2S", "1S1G", "2S1G", "2S2G"],
        );
        for &scale in &scales {
            let g = build_workload(Workload::Rmat(scale), 42, alg);
            let mut row = vec![format!("RMAT{scale}")];
            for hw in configs {
                let cfg = match EngineConfig::from_notation(hw, alpha, strategy, 1) {
                    Ok(c) => c.with_artifacts(&artifacts),
                    Err(_) => {
                        row.push("-".into());
                        continue;
                    }
                };
                match measure(&g, RunSpec::new(alg), &cfg, reps) {
                    Ok(m) => {
                        row.push(fmt_teps(m.teps));
                        all.push(obj(vec![
                            ("alg", s(alg.name())),
                            ("scale", num(scale as f64)),
                            ("hw", s(hw)),
                            ("teps", num(m.teps)),
                            ("makespan", num(m.makespan_secs)),
                        ]));
                    }
                    Err(_) => row.push("-".into()),
                }
            }
            t.row(row);
        }
        md.push_str(&t.markdown());
        md.push('\n');
    }
    print!("{md}");
    save("fig23_scalability", &md, &obj(vec![("rows", arr(all))])).unwrap();
    eprintln!("fig23_scalability: done");
}
