//! Table 5 (paper §9.4): memory footprint of an accelerator partition per
//! algorithm — graph representation, inbox/outbox buffers, and algorithm
//! state — at the maximum offload that fits.
//!
//! Paper shape to reproduce: the graph structure takes over half the
//! space (most for SSSP, which carries edge weights); communication
//! buffers ≈ a quarter; algorithm state under ~10% for single-array
//! algorithms, more for BC (5 arrays).

use totem::engine::EngineConfig;
use totem::graph::{generator, CsrGraph, RmatParams, Workload};
use totem::harness::{measure, RunSpec, ALL_ALGS};
use totem::partition::Strategy;
use totem::report::{save, Table};
use totem::util::args::Args;
use totem::util::fmt_bytes;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("table5_memory: SKIP (run `make artifacts`)");
        return;
    }
    let alpha = args.f64_or("alpha", 0.6).unwrap();
    let mut el = if args.has("full") {
        Workload::TwitterProxy.generate(7)
    } else {
        generator::rmat(&RmatParams {
            scale: 14,
            avg_degree: 36,
            a: 0.60,
            b: 0.19,
            c: 0.19,
            permute: true,
            seed: 7,
        })
    };
    generator::with_random_weights(&mut el, generator::WEIGHT_MAX_DEFAULT, 9);
    let g = CsrGraph::from_edge_list(&el);

    let mut t = Table::new(
        "Table 5: accelerator-partition memory footprint (Twitter proxy, max offload, LOW)",
        &["algorithm", "|V|", "|E|", "graph repr", "inbox", "outbox", "alg state", "total"],
    );
    let mut rows = Vec::new();
    // Host-side accounting (DESIGN.md §12.6): measured process peak RSS
    // plus per-structure attribution, not just the modeled partition
    // formulas — so the "graph ≈ half the space" Table 5 claim is checked
    // against what the process actually commits.
    let mut host = Table::new(
        "Host-side memory accounting (peak RSS + per-structure attribution)",
        &["algorithm", "graph CSR", "heap-owned", "partitions", "peak RSS"],
    );
    for alg in ALL_ALGS {
        // LOW places the fewest vertices on the accelerator per edge for
        // state-heavy algorithms; paper's Table 5 uses the best-performing
        // configuration's partitions.
        let cfg = EngineConfig::hybrid(1, alpha, Strategy::Low).with_artifacts(&artifacts);
        let spec = RunSpec::new(alg).with_source(1).with_rounds(1);
        let m = match measure(&g, spec, &cfg, 1) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{}: {e:#}", alg.name());
                continue;
            }
        };
        let fp = &m.last.footprints[1];
        t.row(vec![
            alg.name().to_string(),
            fp.vertices.to_string(),
            fp.edges.to_string(),
            fmt_bytes(fp.graph_bytes),
            fmt_bytes(fp.inbox_bytes),
            fmt_bytes(fp.outbox_bytes),
            fmt_bytes(fp.state_bytes),
            fmt_bytes(fp.total()),
        ]);
        host.row(vec![
            alg.name().to_string(),
            fmt_bytes(m.graph_bytes),
            fmt_bytes(m.graph_owned_bytes),
            fmt_bytes(m.partition_bytes),
            m.peak_rss_bytes.map_or_else(|| "n/a".to_string(), fmt_bytes),
        ]);
        rows.push(obj(vec![
            ("alg", s(alg.name())),
            ("vertices", num(fp.vertices as f64)),
            ("edges", num(fp.edges as f64)),
            ("graph_bytes", num(fp.graph_bytes as f64)),
            ("inbox_bytes", num(fp.inbox_bytes as f64)),
            ("outbox_bytes", num(fp.outbox_bytes as f64)),
            ("state_bytes", num(fp.state_bytes as f64)),
            ("host_graph_bytes", num(m.graph_bytes as f64)),
            ("host_partition_bytes", num(m.partition_bytes as f64)),
            ("host_peak_rss_bytes", num(m.peak_rss_bytes.unwrap_or(0) as f64)),
        ]));
    }
    let md = format!("{}{}", t.markdown(), host.markdown());
    print!("{md}");
    save("table5_memory", &md, &obj(vec![("rows", arr(rows))])).unwrap();
    eprintln!("table5_memory: done");
}
