//! Figures 21 & 22 (paper §7.3): SSSP (Bellman-Ford) on the Twitter
//! proxy — rate by strategy (left), breakdown (right), and host memory
//! read/write accesses per strategy vs host-only processing (Fig 22).
//!
//! Paper shape: HIGH is best (atomic distance updates contend on the
//! per-vertex state; fewer CPU vertices → fewer contended writes);
//! communication is negligible; weighted edges double the accelerator's
//! edge footprint (SSSP partitions need the weight array).

use totem::engine::EngineConfig;
use totem::graph::{generator, CsrGraph, RmatParams, Workload};
use totem::harness::{measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, fmt_teps, save, Table};
use totem::util::args::Args;
use totem::util::json::{arr, num, obj, s};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env().unwrap();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("fig21_22_sssp: SKIP (run `make artifacts`)");
        return;
    }
    let reps = args.usize_or("reps", 2).unwrap();
    let mut el = if args.has("full") {
        Workload::TwitterProxy.generate(7)
    } else {
        generator::rmat(&RmatParams {
            scale: 14,
            avg_degree: 36,
            a: 0.60,
            b: 0.19,
            c: 0.19,
            permute: true,
            seed: 7,
        })
    };
    generator::with_random_weights(&mut el, 64, 9);
    let g = CsrGraph::from_edge_list(&el);
    eprintln!("workload: |V|={} |E|={} (weighted)", g.vertex_count, g.edge_count());
    let spec = RunSpec::new(AlgKind::Sssp).with_source(1);

    let host_cfg = EngineConfig::host_only(1).with_instrument(true);
    let host = measure(&g, spec, &host_cfg, reps).expect("host");
    let host_reads = host.last.metrics.mem[0].reads as f64;
    let host_writes = host.last.metrics.mem[0].writes as f64;

    let mut t21 = Table::new(
        "Fig 21: SSSP rate and breakdown by strategy (2S2G, alpha=0.65)",
        &["strategy", "rate", "vs host", "total", "cpu", "accel", "comm"],
    );
    let mut t22 = Table::new(
        "Fig 22: host memory accesses vs host-only",
        &["strategy", "reads %", "writes %", "cpu verts"],
    );
    let mut rows = Vec::new();
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        let cfg = EngineConfig::hybrid(2, 0.65, strat)
            .with_artifacts(&artifacts)
            .with_instrument(true);
        let m = match measure(&g, spec, &cfg, reps) {
            Ok(m) => m,
            Err(_) => {
                t21.row(vec![
                    strat.name().into(),
                    "does not fit".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
        };
        let r = &m.last;
        let acc: f64 = (1..3).map(|p| r.metrics.partition_compute_secs(p)).sum();
        t21.row(vec![
            strat.name().into(),
            fmt_teps(m.teps),
            format!("{:.2}x", host.makespan_secs / m.makespan_secs),
            fmt_secs(m.makespan_secs),
            fmt_secs(r.metrics.partition_compute_secs(0)),
            fmt_secs(acc),
            fmt_secs(m.comm_secs),
        ]);
        t22.row(vec![
            strat.name().into(),
            format!("{:.0}%", 100.0 * r.metrics.mem[0].reads as f64 / host_reads),
            format!("{:.0}%", 100.0 * r.metrics.mem[0].writes as f64 / host_writes),
            r.vertices[0].to_string(),
        ]);
        rows.push(obj(vec![
            ("strategy", s(strat.name())),
            ("teps", num(m.teps)),
            ("reads_pct", num(r.metrics.mem[0].reads as f64 / host_reads)),
            ("writes_pct", num(r.metrics.mem[0].writes as f64 / host_writes)),
        ]));
    }

    let md = format!(
        "host-only SSSP rate: {}\n\n{}\n{}",
        fmt_teps(host.teps),
        t21.markdown(),
        t22.markdown()
    );
    print!("{md}");
    save(
        "fig21_22_sssp",
        &md,
        &obj(vec![("host_teps", num(host.teps)), ("rows", arr(rows))]),
    )
    .unwrap();
    eprintln!("fig21_22_sssp: done");
}
