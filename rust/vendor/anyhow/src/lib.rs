//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment for this repository has no crates.io access, so
//! the small slice of `anyhow` the codebase uses is vendored here as a
//! path dependency (DESIGN.md §6): [`Error`] as a message chain,
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, [`Context`] for both
//! `Result` and `Option`, and `From` conversions for standard error types.
//!
//! Semantics match upstream where it matters to this codebase:
//! `format!("{err}")` prints the outermost message, `format!("{err:#}")`
//! prints the whole chain joined with `": "`, and `.context(c)` makes `c`
//! the new outermost message.

use std::fmt;

/// An error: an outermost message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with a new outermost context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let joined: Vec<&str> = self.chain().collect();
            write!(f, "{}", joined.join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`, same
// as upstream anyhow — that is what makes the blanket conversion below
// coherent with the identity `From`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as message links.
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(err) = cur {
            msgs.push(err.to_string());
            cur = err.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut acc = Error { msg: it.next().unwrap_or_default(), source: None };
        for m in it {
            acc = acc.context(m);
        }
        acc
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, as in upstream anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading manifest: "), "{full}");
        assert!(full.contains("no such file"), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing field");
        assert_eq!(Some(7u32).context("x").unwrap(), 7);
    }

    #[test]
    fn macros_and_bail() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {}", flag);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        let e = f(true).unwrap_err();
        assert_eq!(format!("{e}"), "flag was true");
        let e2 = anyhow!("code {}", 42);
        assert_eq!(format!("{e2}"), "code 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("inner"));
    }

    #[test]
    fn root_cause_is_innermost() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(e.root_cause(), "inner");
        assert_eq!(e.chain().count(), 3);
    }
}
