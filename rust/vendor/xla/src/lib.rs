//! Offline stub of the PJRT (`xla`) crate surface used by
//! `totem::runtime` (DESIGN.md §6).
//!
//! The real backend AOT-compiles JAX/Pallas step programs and executes
//! them through the PJRT C API. That native closure cannot be vendored
//! into this offline build, so this stub preserves the exact API shape —
//! client construction, HLO parsing, buffer upload, execution — and fails
//! **at program compile time** with an actionable message. Everything the
//! engine validates *before* compilation (manifest loading, size-class
//! selection, dtype/spec checks, memory budgets) runs for real, so the
//! failure-mode tests and all CPU-partition paths are fully exercised.
//!
//! Swapping the real backend back in is a one-line change in the
//! workspace manifest (point the `xla` path dependency at the native
//! crate); no `totem` source changes.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's role: `Display` + `Debug`.
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const STUB_MSG: &str = "PJRT backend unavailable in this offline build \
     (vendored xla stub) — link the native xla crate to run accelerator \
     partitions";

/// A PJRT device handle (only ever passed as `None` by the engine).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// PJRT client handle.
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client construction succeeds so that everything ahead of HLO
    /// compilation (manifest selection, spec validation) runs for real.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(STUB_MSG))
    }

    /// Host→device upload. Accepts and drops the data; any real execution
    /// attempt fails at `compile` long before a buffer is consumed.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let expect: usize = dims.iter().product();
        if expect != data.len() {
            return Err(XlaError::new(format!(
                "buffer_from_host_buffer: {} elements for dims {dims:?}",
                data.len()
            )));
        }
        Ok(PjRtBuffer { len: data.len() })
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(Path::new(path))
            .map_err(|e| XlaError::new(format!("{path}: {e}")))?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(XlaError::new(format!("{path}: not an HLO text module")));
        }
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle. Unconstructible through the stub (`compile`
/// always errors), but the execution surface must still typecheck.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    len: usize,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(STUB_MSG))
    }
}

/// Host literal handle (tuple results decompose into these).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(XlaError::new(STUB_MSG))
    }

    pub fn copy_raw_to<T: Copy>(&self, _dst: &mut [T]) -> Result<()> {
        Err(XlaError::new(STUB_MSG))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(XlaError::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_and_uploads() {
        let c = PjRtClient::cpu().unwrap();
        let buf = c.buffer_from_host_buffer(&[1i32, 2, 3], &[3], None).unwrap();
        assert_eq!(buf.len, 3);
        assert!(c.buffer_from_host_buffer(&[1i32], &[2], None).is_err());
    }

    #[test]
    fn compile_fails_with_actionable_message() {
        let dir = std::env::temp_dir().join(format!("xla_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.hlo.txt");
        std::fs::write(&p, "HloModule test\n").unwrap();
        let proto = HloModuleProto::from_text_file(p.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let err = PjRtClient::cpu().unwrap().compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("offline"), "{err}");
    }

    #[test]
    fn garbage_hlo_rejected() {
        let dir = std::env::temp_dir().join(format!("xla_stub_g_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.hlo.txt");
        std::fs::write(&p, "not hlo").unwrap();
        assert!(HloModuleProto::from_text_file(p.to_str().unwrap()).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
