#!/usr/bin/env python3
"""Independent cross-check of the query-serving layer (DESIGN.md §13).

Re-implements, in pure Python, the three contracts the serving layer
rests on and checks them offline (no toolchain, no network):

  1. **Lane packing** (`serve/batch.rs::select_batch`): FIFO head anchor,
     source-dedup lane joins, `min(max_batch, 64)` lane budget,
     non-batchable queries never reordered. Pinned vectors mirror the
     Rust unit tests; a seeded sweep checks the invariants on random
     query streams.
  2. **Bit-parallel MS-BFS** (`alg/program.rs::bit_traversal`): a
     word-level simulation of the two-phase kernel (Phase A settle
     next→seen + stamp lane levels, Phase B OR frontier words into
     targets) must match one plain BFS per source, lane-for-lane, on
     mirrored R-MAT graphs.
  3. **Graph fingerprint** (`serve/cache.rs::graph_fingerprint`): FNV-1a
     over n, m, weightedness and strided CSR samples — the cache identity
     key. Pinned here so the Rust side cannot drift silently; with
     `--totem` the fingerprint the live server prints must match the
     Python mirror, and served BFS level dumps must equal Python BFS on
     the mirrored graph.

Exit 0 with a PASS summary, non-zero with the first failure.
"""

import argparse
import os
import re
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from cross_sim_bench import Csr, Rng, rmat_paper
from tcsr_v2 import fnv1a64

INF_I32 = 1 << 30
MAX_LANES = 64
FINGERPRINT_SAMPLES = 1024

_passed = []


def check(name, cond, detail=""):
    if not cond:
        print("FAIL %s%s" % (name, (": " + detail) if detail else ""))
        sys.exit(1)
    _passed.append(name)
    print("ok   %s" % name)


# ---------------------------------------------------------------------------
# 1. serve/batch.rs mirror
# ---------------------------------------------------------------------------

# A query is ("bfs", src) | ("reach", src) | ("sssp", src) | ("pagerank",)


def lane_source(q):
    return q[1] if q[0] in ("bfs", "reach") else None


def select_batch(kinds, max_batch):
    budget = max(1, min(max_batch, MAX_LANES))
    assert lane_source(kinds[0]) is not None, "head must be lane-batchable"
    picked, lane_sources, lane_of = [], [], []
    for i, k in enumerate(kinds):
        src = lane_source(k)
        if src is None:
            continue
        if src in lane_sources:
            picked.append(i)
            lane_of.append(lane_sources.index(src))
        elif len(lane_sources) < budget:
            picked.append(i)
            lane_of.append(len(lane_sources))
            lane_sources.append(src)
    return picked, lane_sources, lane_of


def check_lane_packing():
    # pinned vectors, mirroring serve/batch.rs unit tests
    p, ls, lo = select_batch([("bfs", 5), ("reach", 7), ("bfs", 9)], 64)
    check("batch.fifo", (p, ls, lo) == ([0, 1, 2], [5, 7, 9], [0, 1, 2]))
    p, ls, lo = select_batch([("bfs", 5), ("reach", 5), ("bfs", 5), ("bfs", 8)], 64)
    check("batch.dedup", (p, ls, lo) == ([0, 1, 2, 3], [5, 8], [0, 0, 0, 1]))
    p, ls, lo = select_batch(
        [("bfs", 1), ("pagerank",), ("sssp", 2), ("bfs", 3)], 64)
    check("batch.nonbatchable", (p, ls) == ([0, 3], [1, 3]))
    p, ls, lo = select_batch([("bfs", 1), ("bfs", 2), ("bfs", 3), ("bfs", 1)], 2)
    check("batch.budget_joins", (p, ls, lo) == ([0, 1, 3], [1, 2], [0, 1, 0]))
    p, ls, lo = select_batch([("bfs", s) for s in range(100)], 1000)
    check("batch.clamp64", len(ls) == MAX_LANES and len(p) == MAX_LANES)

    # seeded invariant sweep
    rng = Rng(0xBA7C4)
    for it in range(200):
        n = 1 + rng.below(40)
        kinds = []
        for _ in range(n):
            r = rng.below(4)
            if r == 0:
                kinds.append(("bfs", rng.below(8)))
            elif r == 1:
                kinds.append(("reach", rng.below(8)))
            elif r == 2:
                kinds.append(("sssp", rng.below(8)))
            else:
                kinds.append(("pagerank",))
        if lane_source(kinds[0]) is None:
            continue
        budget = 1 + rng.below(70)
        picked, lane_sources, lane_of = select_batch(kinds, budget)
        label = "iter %d kinds=%r budget=%d" % (it, kinds, budget)
        # head anchors; pick order is FIFO; lanes are first-seen order
        assert picked[0] == 0, label
        assert picked == sorted(picked), label
        assert len(lane_sources) == len(set(lane_sources)) <= min(budget, MAX_LANES), label
        for j, i in enumerate(picked):
            assert lane_sources[lane_of[j]] == lane_source(kinds[i]), label
        # completeness: an unpicked batchable query must have a new source
        # (joins are unconditional) and the lane budget must be full
        for i, k in enumerate(kinds):
            src = lane_source(k)
            if src is None:
                assert i not in picked, label
            elif i not in picked:
                assert src not in lane_sources, label
                assert len(lane_sources) == min(budget, MAX_LANES), label
    check("batch.invariant_sweep", True)


# ---------------------------------------------------------------------------
# 2. bit-parallel MS-BFS kernel mirror
# ---------------------------------------------------------------------------

MASK64 = (1 << 64) - 1


def plain_bfs(g, src):
    levels = [INF_I32] * g.n
    levels[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for v in frontier:
            for t in g.targets(v):
                if levels[t] == INF_I32:
                    levels[t] = d
                    nxt.append(t)
        frontier = nxt
    return levels


def msbfs_words(g, sources):
    """Word-level simulation of Kernel::BitTraversal's two-phase cycle."""
    lanes = len(sources)
    nxt = [0] * g.n
    seen = [0] * g.n
    frontier = [0] * g.n
    levels = [[INF_I32] * g.n for _ in range(lanes)]
    for b, s in enumerate(sources):
        nxt[s] |= 1 << b
    level = 0
    while True:
        changed = False
        # Phase A: settle next into seen, stamp levels for new bits
        for v in range(g.n):
            new = nxt[v] & ~seen[v] & MASK64
            if new:
                changed = True
                seen[v] |= new
                bits = new
                while bits:
                    b = (bits & -bits).bit_length() - 1
                    levels[b][v] = level
                    bits &= bits - 1
            frontier[v] = new
            nxt[v] = 0
        # Phase B: OR frontier words into targets
        for v in range(g.n):
            w = frontier[v]
            if not w:
                continue
            for t in g.targets(v):
                if w & ~nxt[t] & MASK64:
                    changed = True
                nxt[t] |= w
        if not changed:
            return seen, levels
        level += 1


def check_msbfs():
    for scale, seed in ((6, 9), (7, 3)):
        n, edges = rmat_paper(scale, seed)
        g = Csr(n, edges)
        rng = Rng(seed ^ 0x15)
        sources = [rng.below(n) for _ in range(MAX_LANES)]
        seen, lanes = msbfs_words(g, sources)
        for b, s in enumerate(sources):
            want = plain_bfs(g, s)
            if lanes[b] != want:
                diff = next(v for v in range(n) if lanes[b][v] != want[v])
                check("msbfs.lane", False,
                      "rmat%d/%d lane %d (source %d) differs at vertex %d" %
                      (scale, seed, b, s, diff))
        for v in range(n):
            for b in range(MAX_LANES):
                assert ((seen[v] >> b) & 1 == 1) == (lanes[b][v] != INF_I32), \
                    "seen bit %d of vertex %d contradicts its lane" % (b, v)
        check("msbfs.rmat%d_%d_64lane" % (scale, seed), True)
    # duplicate sources fill identical lanes
    n, edges = rmat_paper(6, 2)
    g = Csr(n, edges)
    seen, lanes = msbfs_words(g, [4, 4, 9])
    check("msbfs.duplicate_sources", lanes[0] == lanes[1] and lanes[0] == plain_bfs(g, 4))


# ---------------------------------------------------------------------------
# 3. graph fingerprint mirror (serve/cache.rs)
# ---------------------------------------------------------------------------


def graph_fingerprint(off, tgt, weighted):
    n = len(off) - 1
    m = len(tgt)
    h = fnv1a64((n & MASK64).to_bytes(8, "little"))
    h = fnv1a64((m & MASK64).to_bytes(8, "little"), h)
    h = fnv1a64(int(weighted).to_bytes(8, "little"), h)
    stride = max(1, len(off) // FINGERPRINT_SAMPLES)
    for i in range(0, len(off), stride):
        h = fnv1a64(off[i].to_bytes(8, "little"), h)
    stride = max(1, len(tgt) // FINGERPRINT_SAMPLES)
    for i in range(0, len(tgt), stride):
        h = fnv1a64(tgt[i].to_bytes(8, "little"), h)
    return h


def check_fingerprint():
    n1, e1 = rmat_paper(6, 9)
    g1 = Csr(n1, e1)
    f1 = graph_fingerprint(g1.off, g1.tgt, False)
    f1b = graph_fingerprint(g1.off, g1.tgt, False)
    check("fingerprint.reproducible", f1 == f1b)
    n2, e2 = rmat_paper(6, 10)
    g2 = Csr(n2, e2)
    check("fingerprint.distinguishes",
          f1 != graph_fingerprint(g2.off, g2.tgt, False))
    check("fingerprint.weightedness",
          f1 != graph_fingerprint(g1.off, g1.tgt, True))


# ---------------------------------------------------------------------------
# 4. [--totem] live serve run vs the mirrors
# ---------------------------------------------------------------------------


def check_live(totem):
    scale, seed = 7, 42
    n, edges = rmat_paper(scale, seed)
    g = Csr(n, edges)
    want_fp = graph_fingerprint(g.off, g.tgt, False)
    sources = [0, 3, n - 1]
    with tempfile.TemporaryDirectory() as d:
        qfile = os.path.join(d, "queries.txt")
        with open(qfile, "w") as f:
            for s in sources:
                f.write("bfs %d\n" % s)
        dump = os.path.join(d, "dump")
        proc = subprocess.run(
            [totem, "serve", "--workload", "rmat%d" % scale, "--seed",
             str(seed), "--queries", qfile, "--dump-dir", dump,
             "--serve-workers", "1", "--threads", "2"],
            capture_output=True, text=True)
        check("live.exit0", proc.returncode == 0, proc.stderr[-2000:])
        m = re.search(r"graph fingerprint ([0-9a-f]{16})", proc.stderr)
        check("live.fingerprint_printed", m is not None, proc.stderr[-2000:])
        check("live.fingerprint_matches", int(m.group(1), 16) == want_fp,
              "rust %s python %016x" % (m.group(1), want_fp))
        for i, s in enumerate(sources):
            want = plain_bfs(g, s)
            path = os.path.join(dump, "q%04d_bfs.txt" % i)
            got = [None] * n
            with open(path) as f:
                for line in f:
                    v, x = line.split()
                    got[int(v)] = int(x)
            check("live.bfs_%d_levels" % s, got == want,
                  "first diff at vertex %d" %
                  next((v for v in range(n) if got[v] != want[v]), -1))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--totem", help="path to a built totem binary for live checks")
    args = ap.parse_args()
    check_lane_packing()
    check_msbfs()
    check_fingerprint()
    if args.totem:
        check_live(args.totem)
    else:
        print("skip live checks (--totem not given)")
    print("PASS %d checks" % len(_passed))


if __name__ == "__main__":
    main()
