#!/usr/bin/env python3
"""Independent cross-check of the out-of-core ingest path (DESIGN.md §12).

Re-implements the v2 container byte contract, the spill-run external sort,
and the two-pass streaming R-MAT in pure Python (via `tcsr_v2.py` and
`cross_sim_bench.py`, the mirrors of `store.rs` / `ingest.rs` /
`generator.rs` / `util/rng.rs`) and checks them against each other — and,
when `--totem` points at a built binary, against bytes the Rust CLI
actually wrote.

Checks:
  1. FNV-1a 64 pinned test vectors.
  2. Canonical layout pin for the reference example in tcsr_v2_layout.json.
  3. Encode/decode roundtrip + exhaustive single-byte-flip corruption sweep
     (every byte of a v2 file is covered by a checksum, a zero-padding
     check, or the exact-length check) + truncation/trailing-bytes checks.
  4. Spill-run external sort (chunk → stable sort by src → k-way merge
     keyed (src, run_index)) reproduces the counting-sort CSR exactly,
     across run sizes — the stability argument in ingest.rs.
  5. Two-pass streaming R-MAT (replay edge draws, take the permutation,
     regenerate) is bit-equal to the in-memory generator.
  6. Harness weight convention (batch draw) == streaming weight convention
     (interleaved draw): same RNG, same order.
  7. [--totem] `totem convert` output bytes == Python `encode()` of the
     mirrored graph, and the text edge-list export matches the mirrored
     edge stream + weights.

Exit 0 with a PASS summary, non-zero with the first failure.
"""

import argparse
import heapq
import os
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import tcsr_v2
from cross_sim_bench import Csr, Rng, random_weights, rmat_paper

WEIGHT_MAX_DEFAULT = 64


def weight_seed(seed):
    return seed ^ 0x5EED


_passed = []


def check(name, cond, detail=""):
    if not cond:
        print("FAIL %s%s" % (name, (": " + detail) if detail else ""))
        sys.exit(1)
    _passed.append(name)
    print("ok   %s" % name)


# -- 1. FNV vectors ----------------------------------------------------------


def check_fnv():
    vectors = {
        b"": 0xCBF29CE484222325,
        b"a": 0xAF63DC4C8601EC8C,
        b"foobar": 0x85944171F73967E8,
    }
    for data, want in vectors.items():
        got = tcsr_v2.fnv1a64(data)
        check("fnv1a64(%r)" % data, got == want, "got %#x want %#x" % (got, want))


# -- 2. layout pin -----------------------------------------------------------


def check_layout_pin():
    lay = tcsr_v2.layout_for(5, 9, True)
    check("layout(5,9,weighted).header", lay["header_bytes"] == 144, str(lay))
    offs = [s["offset"] for s in lay["sections"]]
    check("layout(5,9,weighted).offsets", offs == [144, 192, 232], str(offs))
    check("layout(5,9,weighted).total", lay["total_bytes"] == 268, str(lay))
    lay = tcsr_v2.layout_for(5, 9, False)
    check(
        "layout(5,9,unweighted)",
        lay["header_bytes"] == 112 and lay["total_bytes"] == 196,
        str(lay),
    )


# -- 3. roundtrip + corruption sweep ----------------------------------------


def check_roundtrip_and_corruption():
    # Roundtrip on a real generated graph.
    n, edges = rmat_paper(5, 13)
    w = random_weights(len(edges), 16, 99)
    g = Csr(n, edges, w)
    data = tcsr_v2.encode(g.off, g.tgt, g.wgt)
    ro, ci, wt = tcsr_v2.decode(data)
    check(
        "roundtrip rmat(5)",
        ro == g.off and ci == g.tgt and wt == g.wgt,
        "decode disagrees with encode input",
    )
    # Unweighted too.
    g2 = Csr(n, edges)
    d2 = tcsr_v2.encode(g2.off, g2.tgt)
    ro2, ci2, wt2 = tcsr_v2.decode(d2)
    check("roundtrip unweighted", ro2 == g2.off and ci2 == g2.tgt and wt2 is None)

    # Exhaustive byte-flip sweep on a tiny container (every byte is covered
    # by the header checksum, a section checksum, the zero-padding check, or
    # the magic/version/layout comparisons).
    tiny_edges = [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 0), (4, 2), (2, 0)]
    tiny = Csr(5, tiny_edges, [float(i + 1) for i in range(len(tiny_edges))])
    tdata = bytearray(tcsr_v2.encode(tiny.off, tiny.tgt, tiny.wgt))
    undetected = []
    for i in range(len(tdata)):
        tdata[i] ^= 0xFF
        try:
            tcsr_v2.decode(bytes(tdata))
            undetected.append(i)
        except ValueError:
            pass
        tdata[i] ^= 0xFF
    check(
        "byte-flip sweep (%d bytes)" % len(tdata),
        not undetected,
        "flips not detected at offsets %s" % undetected[:10],
    )
    # Truncation at several boundaries, and trailing garbage.
    for cut in (0, 4, 39, 40, len(tdata) // 2, len(tdata) - 1):
        try:
            tcsr_v2.decode(bytes(tdata[:cut]))
            check("truncation at %d" % cut, False, "accepted truncated file")
        except ValueError:
            pass
    check("truncation sweep", True)
    try:
        tcsr_v2.decode(bytes(tdata) + b"xyz")
        check("trailing bytes", False, "accepted trailing garbage")
    except ValueError as e:
        check("trailing bytes", "trailing" in str(e), str(e))


# -- 4. spill-run external sort == counting sort ----------------------------


def spill_merge(n, edges, weights, run_edges):
    """Mirror of ingest.rs SpillBuild: chunk the stream into runs of
    `run_edges`, stable-sort each run by src, k-way merge with ties broken
    by run index. Returns the CSR arrays built from the merged stream."""
    recs = [
        (s, d, weights[i] if weights is not None else 0.0)
        for i, (s, d) in enumerate(edges)
    ]
    runs = [
        sorted(recs[i : i + run_edges], key=lambda r: r[0])
        for i in range(0, len(recs), run_edges)
    ]
    heap = [(run[0][0], ri, 0) for ri, run in enumerate(runs) if run]
    heapq.heapify(heap)
    tgt, wgt = [], []
    off = [0] * (n + 1)
    while heap:
        src, ri, k = heapq.heappop(heap)
        _, d, w = runs[ri][k]
        off[src + 1] += 1
        tgt.append(d)
        wgt.append(w)
        if k + 1 < len(runs[ri]):
            heapq.heappush(heap, (runs[ri][k + 1][0], ri, k + 1))
    for v in range(n):
        off[v + 1] += off[v]
    return off, tgt, (wgt if weights is not None else None)


def check_spill_merge():
    n, edges = rmat_paper(7, 21)
    w = random_weights(len(edges), WEIGHT_MAX_DEFAULT, weight_seed(21))
    direct = Csr(n, edges, w)
    for run_edges in (7, 100, 1000, 10_000):
        off, tgt, wgt = spill_merge(n, edges, w, run_edges)
        check(
            "spill merge == counting sort (runs of %d)" % run_edges,
            off == direct.off and tgt == direct.tgt and wgt == direct.wgt,
            "merged stream order diverges from counting-sort order",
        )
    # Unweighted.
    direct_u = Csr(n, edges)
    off, tgt, wgt = spill_merge(n, edges, None, 64)
    check(
        "spill merge unweighted",
        off == direct_u.off and tgt == direct_u.tgt and wgt is None,
    )


# -- 5. streaming two-pass R-MAT == in-memory -------------------------------


def rmat_paper_streaming(scale, seed):
    """Mirror of generator.rs rmat_streaming: replay the m*scale edge draws
    to position the RNG at the permutation, then regenerate edges with a
    fresh RNG applying the permutation on the fly."""
    a, b, c = 0.57, 0.19, 0.19
    n = 1 << scale
    m = n * 16
    rng = Rng(seed)
    for _ in range(m * scale):
        rng.next_f64()
    perm = rng.permutation(n)
    rng = Rng(seed)
    out = []
    for _ in range(m):
        x = y = 0
        for level in range(scale - 1, -1, -1):
            r = rng.next_f64()
            bit = 1 << level
            if r < a:
                pass
            elif r < a + b:
                y |= bit
            elif r < a + b + c:
                x |= bit
            else:
                x |= bit
                y |= bit
        out.append((perm[x], perm[y]))
    return n, out


def check_streaming_rmat():
    for scale, seed in ((5, 42), (7, 9)):
        n_a, mem = rmat_paper(scale, seed)
        n_b, streamed = rmat_paper_streaming(scale, seed)
        check(
            "streaming rmat(%d, seed %d) bit-equal" % (scale, seed),
            n_a == n_b and mem == streamed,
            "two-pass replay diverges from in-memory generator",
        )


# -- 6. weight convention: batch draw == interleaved draw -------------------


def check_weight_convention():
    m, seed = 500, 42
    batch = random_weights(m, WEIGHT_MAX_DEFAULT, weight_seed(seed))
    rng = Rng(weight_seed(seed))
    interleaved = []
    for _ in range(m):
        interleaved.append(float(1 + rng.below(WEIGHT_MAX_DEFAULT)))
        # ...an edge would be emitted here; the weight RNG is independent
        # of the edge RNG, so interleaving cannot change the stream.
    check("weight convention batch == interleaved", batch == interleaved)
    check(
        "weights are integer-valued in [1, 64]",
        all(w == int(w) and 1 <= w <= 64 for w in batch),
    )


# -- 7. optional: cross-check the Rust binary's actual bytes ----------------


def parse_el(path):
    vertices = edges_declared = None
    edges, weights = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            if line.startswith("p "):
                _, v, e = line.split()
                vertices, edges_declared = int(v), int(e)
                continue
            parts = line.split()
            edges.append((int(parts[0]), int(parts[1])))
            if len(parts) > 2:
                weights.append(float(parts[2]))
    return vertices, edges_declared, edges, (weights or None)


def check_against_binary(totem):
    scale, seed = 10, 42
    n, edges = rmat_paper(scale, seed)
    w = random_weights(len(edges), WEIGHT_MAX_DEFAULT, weight_seed(seed))
    g = Csr(n, edges, w)
    expect = tcsr_v2.encode(g.off, g.tgt, g.wgt)
    with tempfile.TemporaryDirectory(prefix="totem_xcheck_") as td:
        tcsr = os.path.join(td, "x.tcsr")
        el = os.path.join(td, "x.el")
        subprocess.run(
            [totem, "convert", "rmat%d" % scale, tcsr, "--weights",
             "--spill-edges", "3000"],
            check=True,
        )
        with open(tcsr, "rb") as f:
            got = f.read()
        check(
            "rust `totem convert rmat%d` bytes == python encode" % scale,
            got == expect,
            "file is %d bytes, python expects %d; first difference at %d"
            % (
                len(got),
                len(expect),
                next(
                    (i for i, (a, b) in enumerate(zip(got, expect)) if a != b),
                    min(len(got), len(expect)),
                ),
            ),
        )
        subprocess.run(
            [totem, "convert", "rmat%d" % scale, el, "--weights"], check=True
        )
        v, e_decl, got_edges, got_w = parse_el(el)
        check(
            "rust text export header",
            v == n and e_decl == len(edges),
            "p %s %s" % (v, e_decl),
        )
        check("rust text export edges", got_edges == edges)
        check("rust text export weights", got_w == w)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--totem",
        help="path to a built totem binary; enables the Rust-vs-Python "
        "byte comparison (CI). Omit for the pure-Python checks only.",
    )
    args = ap.parse_args()

    check_fnv()
    check_layout_pin()
    check_roundtrip_and_corruption()
    check_spill_merge()
    check_streaming_rmat()
    check_weight_convention()
    if args.totem:
        if not os.path.exists(args.totem):
            print("FAIL --totem binary not found: %s" % args.totem)
            sys.exit(1)
        check_against_binary(args.totem)
    else:
        print("note: --totem not given, skipping Rust-binary byte comparison")

    print("\nPASS: %d ingest cross-checks" % len(_passed))


if __name__ == "__main__":
    main()
