#!/usr/bin/env python3
"""Independent cross-check of streaming mutations (DESIGN.md §14).

Re-implements, in pure Python, the delta-log contracts the mutation
layer rests on, and drives them against a built `totem` binary:

  1. **Seeded batch generation** (`DeltaBatch::seeded`): same
     Xoshiro256** stream, same op mix — each op is a delete of a
     uniformly sampled existing edge (CSR enumeration order) with
     probability `delete_frac`, else an insert between uniform
     endpoints, weighted iff the graph is. `emit` writes the replay
     file `totem run --mutations` consumes, so the CI workload is
     deterministic without any Rust-side generator CLI.
  2. **Batch application** (`delta::apply`, §14.1): deletes resolve
     against the pre-batch graph and remove ALL parallel copies of a
     named pair; inserts append afterward in op order; endpoint growth;
     per-unique-pair miss accounting. `verify` recomputes every
     per-batch counter (+N / -M edges, misses, new vertices) and checks
     them against the `[mutate]` lines totem printed during replay.
  3. **End-to-end answers**: BFS levels on the Python-applied final
     graph (source = pre-mutation max-degree vertex, the AUTO rule)
     must equal the per-vertex dump of
     `totem run --mutations … --dump-output` — one oracle for both
     `--mutate-mode incremental` and `full`, which CI has already
     diffed against each other.

Exit 0 with a PASS summary, non-zero with the first failure.
"""

import argparse
import os
import re
import sys
from collections import deque

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from cross_sim_bench import Rng

INF_I32 = 1 << 30

failures = []


def check(name, cond, detail=""):
    tag = "PASS" if cond else "FAIL"
    print(f"[{tag}] {name}" + (f" — {detail}" if detail and not cond else ""))
    if not cond:
        failures.append(name)


# ---------------------------------------------------------------------------
# graph/io.rs text edge-list parse (the `p V E` grammar)
# ---------------------------------------------------------------------------


def parse_edge_list(path):
    """Returns (n, edges, weights|None); n from the `p` header or max id+1."""
    declared_n = None
    edges, weights = [], None
    with open(path) as f:
        for raw in f:
            s = raw.strip()
            if not s or s.startswith("#"):
                continue
            t = s.split()
            if t[0] == "p":
                declared_n = int(t[1])
                continue
            src, dst = int(t[0]), int(t[1])
            if len(t) == 3:
                if weights is None:
                    weights = []
                weights.append(float(t[2]))
            edges.append((src, dst))
    n = declared_n
    if n is None:
        n = max((max(s, d) for s, d in edges), default=-1) + 1
    return n, edges, weights


def csr_order(n, edges, weights):
    """Counting-sort into CSR enumeration order (mirrors from_edge_list:
    stable within a row), the order `CsrGraph::iter_edges` yields."""
    deg = [0] * n
    for s, _ in edges:
        deg[s] += 1
    off = [0] * (n + 1)
    for v in range(n):
        off[v + 1] = off[v] + deg[v]
    out_e = [None] * len(edges)
    out_w = [0.0] * len(edges) if weights is not None else None
    cur = off[:n]
    for k, (s, d) in enumerate(edges):
        out_e[cur[s]] = (s, d)
        if out_w is not None:
            out_w[cur[s]] = weights[k]
        cur[s] += 1
    return out_e, out_w


# ---------------------------------------------------------------------------
# delta.rs mirrors
# ---------------------------------------------------------------------------


def seeded_batch(n, csr_edges, weighted, n_ops, delete_frac, seed):
    """Mirror of `DeltaBatch::seeded` — including RNG call order: the
    delete coin is flipped only when edges exist, the weight draw only
    when the graph is weighted."""
    rng = Rng(seed)
    nb = max(n, 1)
    ops = []
    for _ in range(n_ops):
        if csr_edges and rng.next_f64() < delete_frac:
            src, dst = csr_edges[rng.below(len(csr_edges))]
            ops.append(("del", src, dst, None))
        else:
            src = rng.below(nb)
            dst = rng.below(nb)
            w = float(rng.below(64) + 1) if weighted else None
            ops.append(("add", src, dst, w))
    return ops


def parse_mutations(path):
    """Mirror of `DeltaBatch::parse_file`: batches split on `commit`,
    trailing ops form a last batch, empty batches dropped."""
    batches, cur = [], []
    with open(path) as f:
        for raw in f:
            s = raw.strip()
            if not s or s.startswith("#"):
                continue
            t = s.split()
            if t[0] == "commit":
                if cur:
                    batches.append(cur)
                    cur = []
            elif t[0] == "add":
                w = float(t[3]) if len(t) > 3 else None
                cur.append(("add", int(t[1]), int(t[2]), w))
            elif t[0] == "del":
                cur.append(("del", int(t[1]), int(t[2]), None))
            else:
                raise ValueError(f"unknown verb {t[0]!r}")
    if cur:
        batches.append(cur)
    return batches


def apply_batch(n, edges, weights, ops):
    """Mirror of `delta::apply`. Returns (n', edges', weights', stats).
    `edges` must be in CSR enumeration order; the result is the exact
    intra-row edge order the Rust rebuild produces (surviving old edges
    in old order, inserts appended in op order, then re-sorted by row)."""
    delete_pairs = set()
    inserts = []
    nv = n
    for verb, src, dst, w in ops:
        if verb == "add":
            nv = max(nv, src + 1, dst + 1)
            inserts.append((src, dst, w))
        else:
            delete_pairs.add((src, dst))
    out_e, out_w = [], [] if weights is not None else None
    deleted, hit = 0, set()
    for k, (s, d) in enumerate(edges):
        if (s, d) in delete_pairs:
            deleted += 1
            hit.add((s, d))
            continue
        out_e.append((s, d))
        if out_w is not None:
            out_w.append(weights[k])
    for src, dst, w in inserts:
        out_e.append((src, dst))
        if out_w is not None:
            out_w.append(w if w is not None else 0.0)
    stats = {
        "inserted": len(inserts),
        "deleted": deleted,
        "misses": len(delete_pairs) - len(hit),
        "new_vertices": nv - n,
    }
    out_e, out_w = csr_order(nv, out_e, out_w)
    return nv, out_e, out_w, stats


# ---------------------------------------------------------------------------
# harness mirrors: AUTO source + baseline BFS
# ---------------------------------------------------------------------------


def auto_source(n, edges):
    """`resolve_source`: max out-degree; Rust's `max_by_key` keeps the
    LAST maximal element on ties."""
    deg = [0] * max(n, 1)
    for s, _ in edges:
        deg[s] += 1
    best = 0
    for v in range(n):
        if deg[v] >= deg[best]:
            best = v
    return best


def bfs_levels(n, edges, source):
    adj = [[] for _ in range(n)]
    for s, d in edges:
        adj[s].append(d)
    lv = [INF_I32] * n
    if n == 0:
        return lv
    lv[source] = 0
    q = deque([source])
    while q:
        v = q.popleft()
        for d in adj[v]:
            if lv[d] == INF_I32:
                lv[d] = lv[v] + 1
                q.append(d)
    return lv


# ---------------------------------------------------------------------------
# commands
# ---------------------------------------------------------------------------


def cmd_emit(args):
    n, edges, weights = parse_edge_list(args.graph)
    csr_e, _ = csr_order(n, edges, weights)
    weighted = weights is not None
    lines = [f"# seeded mutations: graph={os.path.basename(args.graph)} "
             f"seed={args.seed} ops={args.ops}"]
    # batch 1: insert-only (the monotone warm-start path), batch 2:
    # mixed with deletes (the full-fallback path) — CI greps the replay
    # log to prove both strategies actually ran.
    specs = [(0.0, args.seed), (0.4, (args.seed ^ 0xBEEF) & ((1 << 64) - 1))]
    for frac, seed in specs:
        for verb, s, d, w in seeded_batch(n, csr_e, weighted, args.ops, frac, seed):
            if verb == "add" and w is not None:
                lines.append(f"add {s} {d} {int(w)}")
            elif verb == "add":
                lines.append(f"add {s} {d}")
            else:
                lines.append(f"del {s} {d}")
        lines.append("commit")
    with open(args.out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {args.out}: 2 batches x {args.ops} ops over |V|={n} |E|={len(edges)}")


MUTATE_LINE = re.compile(
    r"\[mutate\] batch (\d+): \+(\d+) -(\d+) edges \((\d+) delete misses?, "
    r"(\d+) new vertices\)"
)


def cmd_verify(args):
    n, edges, weights = parse_edge_list(args.graph)
    edges, weights = csr_order(n, edges, weights)
    source = auto_source(n, edges)
    batches = parse_mutations(args.mutations)
    check("mutation file parses into batches", len(batches) > 0,
          f"{args.mutations} held no batches")

    all_stats = []
    for ops in batches:
        n, edges, weights, stats = apply_batch(n, edges, weights, ops)
        all_stats.append(stats)

    if args.log:
        got = []
        with open(args.log) as f:
            for line in f:
                m = MUTATE_LINE.search(line)
                if m:
                    got.append({
                        "inserted": int(m.group(2)),
                        "deleted": int(m.group(3)),
                        "misses": int(m.group(4)),
                        "new_vertices": int(m.group(5)),
                    })
        check("replay log holds one [mutate] line per batch",
              len(got) == len(all_stats),
              f"log {len(got)} vs python {len(all_stats)}")
        for i, (want, have) in enumerate(zip(all_stats, got)):
            check(f"batch {i} counters (+{want['inserted']} -{want['deleted']} "
                  f"misses={want['misses']} grow={want['new_vertices']})",
                  want == have, f"totem printed {have}")

    if args.dump:
        want = bfs_levels(n, edges, source)
        got = {}
        with open(args.dump) as f:
            for line in f:
                t = line.split()
                if len(t) == 2:
                    got[int(t[0])] = int(t[1])
        check("dump covers the post-mutation vertex set", len(got) == n,
              f"dump {len(got)} vs python {n}")
        bad = [(v, got.get(v), want[v]) for v in range(n) if got.get(v) != want[v]]
        check(f"post-mutation BFS levels from source {source} match dump",
              not bad, f"first diff {bad[:3]}")

    print(f"final graph: |V|={n} |E|={len(edges)}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    e = sub.add_parser("emit", help="write a seeded mutation replay file")
    e.add_argument("--graph", required=True, help="text edge list (`p V E` grammar)")
    e.add_argument("--seed", type=lambda s: int(s, 0), default=0xD317A)
    e.add_argument("--ops", type=int, default=64, help="ops per batch")
    e.add_argument("--out", required=True)
    v = sub.add_parser("verify", help="check replay counters + final BFS dump")
    v.add_argument("--graph", required=True, help="PRE-mutation text edge list")
    v.add_argument("--mutations", required=True)
    v.add_argument("--log", help="totem run stderr with the [mutate] lines")
    v.add_argument("--dump", help="per-vertex --dump-output of the replayed BFS run")
    args = ap.parse_args()
    if args.cmd == "emit":
        cmd_emit(args)
    else:
        cmd_verify(args)
    if failures:
        print(f"\n{len(failures)} check(s) FAILED: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
