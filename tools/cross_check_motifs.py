#!/usr/bin/env python3
"""Independent cross-check of the edge-centric workload family
(DESIGN.md §15): triangle counting, k-core, label propagation, and
personalized PageRank.

Re-implements each algorithm's contract in pure Python — with a
*different shape* than both the engine kernels and the `baseline/`
oracles — and checks them offline (no toolchain, no network):

  1. **Committed goldens**: the fixture graphs under `rust/tests/golden/`
     are re-solved here (triangles via oriented a<b<c enumeration, k-core
     via sequential min-degree peel, label propagation via a sorted-run
     scan, PPR via float64 push accumulation) and compared against the
     committed expected files — integer outputs exactly, PPR to float64
     round-off.
  2. **Triangle duality**: oriented-enumeration counts must equal naive
     neighbor-pair probing on mirrored R-MAT graphs.
  3. **Peel duality**: batch-synchronous peeling (the engine's schedule)
     must equal sequential min-degree peeling (Matula-Beck) on the
     undirected multigraph view.
  4. **LP determinism**: the min-label tie-break makes every round a pure
     function of the previous labels — frequency-map and sorted-run
     implementations must agree, and repeated runs must be identical.
  5. **PPR mass**: rank mass stays within (0, 1], the source dominates on
     its own out-star, and PPR with teleport-everywhere degenerates to
     global PageRank's contract.

With `--totem BIN` the live binary is driven too: a `totem run --alg
triangles` dump (u64 hex) must equal the Python oracle exactly, and a
`totem serve` PPR replay (f32-bit hex dumps through admission, batching
skip, and the per-source cache) must match float64 power iteration within
f32 summation tolerance — with repeated sources byte-identical (the
cache may only ever return the same answer). `--big` adds the RMAT18
smoke: cross-configuration determinism diffs and structural invariants
on dumps too large to re-solve in Python.

Exit 0 with a PASS summary, non-zero with the first failure.
"""

import argparse
import os
import re
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from cross_sim_bench import Csr, Rng, rmat_paper

INF_I32 = 1 << 30
DAMPING = 0.85
PR_ROUNDS = 5
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "rust", "tests", "golden")

_passed = []


def check(name, cond, detail=""):
    if not cond:
        print("FAIL %s%s" % (name, (": " + detail) if detail else ""))
        sys.exit(1)
    _passed.append(name)
    print("ok   %s" % name)


# ---------------------------------------------------------------------------
# reference implementations (deliberately shaped unlike baseline/ and the
# engine kernels, so a shared bug cannot cancel out)
# ---------------------------------------------------------------------------


def undirected_simple(n, edges):
    """Deduplicated, self-loop-free undirected closure (triangle view)."""
    adj = [set() for _ in range(n)]
    for s, d in edges:
        if s != d:
            adj[s].add(d)
            adj[d].add(s)
    return adj


def undirected_multi(n, edges):
    """Multigraph view: parallel edges keep multiplicity, self-loops
    double (the engine's `to_undirected`)."""
    und = [[] for _ in range(n)]
    for s, d in edges:
        und[s].append(d)
        und[d].append(s)
    return und


def triangles_probe(n, edges):
    """Per-vertex incident-triangle counts by neighbor-pair probing."""
    adj = undirected_simple(n, edges)
    srt = [sorted(a) for a in adj]
    tri = [0] * n
    for v in range(n):
        a = srt[v]
        for i, w in enumerate(a):
            for u in a[i + 1:]:
                if u in adj[w]:
                    tri[v] += 1
    return tri


def triangles_oriented(n, edges):
    """Per-vertex counts by oriented a<b<c enumeration: every triangle is
    found exactly once at its smallest vertex and credited to all three
    corners. Different traversal order and different credit scheme than
    the probe above."""
    adj = undirected_simple(n, edges)
    up = [sorted(t for t in adj[v] if t > v) for v in range(n)]
    tri = [0] * n
    for a in range(n):
        for i, b in enumerate(up[a]):
            bs = adj[b]
            for c in up[a][i + 1:]:
                if c in bs:
                    tri[a] += 1
                    tri[b] += 1
                    tri[c] += 1
    return tri


def kcore_batch(n, edges):
    """Batch-synchronous peel (the engine's schedule): at threshold k,
    remove every alive vertex with alive-degree <= k per round; a quiet
    round escalates k."""
    und = undirected_multi(n, edges)
    core = [INF_I32] * n
    remaining = n
    k = 0
    while remaining > 0:
        doomed = [
            v
            for v in range(n)
            if core[v] == INF_I32
            and sum(1 for t in und[v] if core[t] == INF_I32) <= k
        ]
        if not doomed:
            k += 1
        else:
            for v in doomed:
                core[v] = k
                remaining -= 1
    return core


def kcore_sequential(n, edges):
    """Sequential min-degree peel (Matula-Beck): one vertex at a time,
    coreness = running max of removal degrees."""
    und = undirected_multi(n, edges)
    deg = [len(und[v]) for v in range(n)]
    alive = [True] * n
    core = [0] * n
    k = 0
    for _ in range(n):
        v = min((v for v in range(n) if alive[v]), key=lambda v: deg[v])
        k = max(k, deg[v])
        core[v] = k
        alive[v] = False
        for t in und[v]:
            if alive[t]:
                deg[t] -= 1
    return core


def labelprop_freq(n, edges, rounds):
    """Synchronous LP via frequency map, min-label tie-break."""
    und = undirected_multi(n, edges)
    label = list(range(n))
    for _ in range(rounds):
        prev = list(label)
        changed = False
        for v in range(n):
            if not und[v]:
                continue
            freq = {}
            for t in und[v]:
                freq[prev[t]] = freq.get(prev[t], 0) + 1
            best = min(freq.items(), key=lambda kv: (-kv[1], kv[0]))[0]
            if best != label[v]:
                label[v] = best
                changed = True
        if not changed:
            break
    return label


def labelprop_sorted(n, edges, rounds):
    """Same contract via the engine's sorted-run scan: sort the incident
    labels ascending, pick the longest run, first (= smallest) run wins
    ties."""
    und = undirected_multi(n, edges)
    label = list(range(n))
    for _ in range(rounds):
        prev = list(label)
        changed = False
        for v in range(n):
            if not und[v]:
                continue
            ls = sorted(prev[t] for t in und[v])
            best, best_len = ls[0], 0
            run, run_len = ls[0], 0
            for x in ls:
                if x == run:
                    run_len += 1
                else:
                    run, run_len = x, 1
                if run_len > best_len:
                    best, best_len = run, run_len
            if best != label[v]:
                label[v] = best
                changed = True
        if not changed:
            break
    return label


def ppr_push(n, edges, src, rounds):
    """Personalized PageRank by float64 per-edge push accumulation:
    teleport (1-d) at the source only, dangling mass dropped."""
    outdeg = [0] * n
    for s, _ in edges:
        outdeg[s] += 1
    rank = [0.0] * n
    rank[src] = 1.0
    for _ in range(rounds):
        acc = [0.0] * n
        for s, d in edges:
            acc[d] += rank[s] / outdeg[s]
        rank = [
            (1.0 - DAMPING if v == src else 0.0) + DAMPING * acc[v]
            for v in range(n)
        ]
    return rank


# ---------------------------------------------------------------------------
# 1. committed goldens
# ---------------------------------------------------------------------------


def read_fixture(name):
    n = None
    edges = []
    with open(os.path.join(GOLDEN, name + ".el")) as f:
        for line in f:
            line = line.split("#")[0].strip()
            if not line:
                continue
            parts = line.split()
            if parts[0] == "p":
                n = int(parts[1])
            else:
                edges.append((int(parts[0]), int(parts[1])))
    assert n is not None, name
    return n, edges


def read_golden(name, alg, parse):
    with open(os.path.join(GOLDEN, "%s.%s.txt" % (name, alg))) as f:
        return [parse(l.strip()) for l in f if l.strip()]


def fixture_source(name, n, edges):
    """The fixtures' source policy: vertex 0 (all committed fixtures
    resolve to it, including rmat64's max-out-degree hub)."""
    return 0


def check_goldens():
    for name in ("chain8", "star8", "twocomm16", "rmat64"):
        n, edges = read_fixture(name)
        src = fixture_source(name, n, edges)

        want = read_golden(name, "triangles", int)
        got = triangles_oriented(n, edges)
        check("golden.%s.triangles" % name, got == want,
              "first diff at %s" %
              next((v for v in range(n) if got[v] != want[v]), -1))

        want = read_golden(name, "kcore", int)
        got = kcore_sequential(n, edges)
        check("golden.%s.kcore" % name, got == want,
              "first diff at %s" %
              next((v for v in range(n) if got[v] != want[v]), -1))

        want = read_golden(name, "labelprop", int)
        got = labelprop_sorted(n, edges, PR_ROUNDS)
        check("golden.%s.labelprop" % name, got == want,
              "first diff at %s" %
              next((v for v in range(n) if got[v] != want[v]), -1))

        want = read_golden(name, "ppr", float)
        got = ppr_push(n, edges, src, PR_ROUNDS)
        bad = next(
            (v for v in range(n)
             if abs(got[v] - want[v]) > 1e-12 + 1e-9 * abs(want[v])),
            None)
        check("golden.%s.ppr" % name, bad is None,
              "vertex %s: %r vs golden %r" %
              (bad, got[bad] if bad is not None else 0,
               want[bad] if bad is not None else 0))


# ---------------------------------------------------------------------------
# 2-5. seeded R-MAT property sweeps
# ---------------------------------------------------------------------------


def check_triangle_duality():
    for scale, seed in ((6, 9), (7, 3)):
        n, edges = rmat_paper(scale, seed)
        probe = triangles_probe(n, edges)
        oriented = triangles_oriented(n, edges)
        check("tri.rmat%d_%d.duality" % (scale, seed), probe == oriented)
        total = sum(oriented)
        check("tri.rmat%d_%d.mod3" % (scale, seed),
              total % 3 == 0 and total > 0,
              "total incident count %d" % total)


def check_peel_duality():
    for scale, seed in ((6, 9), (7, 3)):
        n, edges = rmat_paper(scale, seed)
        batch = kcore_batch(n, edges)
        seq = kcore_sequential(n, edges)
        check("kcore.rmat%d_%d.duality" % (scale, seed), batch == seq,
              "first diff at %s" %
              next((v for v in range(n) if batch[v] != seq[v]), -1))
        # defining property: in the subgraph induced by
        # {u : core(u) >= c}, v has degree >= c = core(v)
        und = undirected_multi(n, edges)
        bad = next(
            (v for v in range(n)
             if sum(1 for t in und[v] if seq[t] >= seq[v]) < seq[v]),
            None)
        check("kcore.rmat%d_%d.property" % (scale, seed), bad is None,
              "vertex %s violates the core property" % bad)


def check_lp_determinism():
    for scale, seed in ((6, 9), (7, 3)):
        n, edges = rmat_paper(scale, seed)
        a = labelprop_freq(n, edges, 6)
        b = labelprop_sorted(n, edges, 6)
        check("lp.rmat%d_%d.duality" % (scale, seed), a == b,
              "first diff at %s" %
              next((v for v in range(n) if a[v] != b[v]), -1))
        check("lp.rmat%d_%d.deterministic" % (scale, seed),
              labelprop_sorted(n, edges, 6) == b)
        # every surviving label names a vertex that carries it
        check("lp.rmat%d_%d.anchored" % (scale, seed),
              all(a[l] == l or 0 <= l < n for l in set(a)))


def check_ppr_mass():
    n, edges = rmat_paper(6, 9)
    src = max(range(n), key=lambda v: sum(1 for s, _ in edges if s == v))
    rank = ppr_push(n, edges, src, PR_ROUNDS)
    mass = sum(rank)
    check("ppr.mass_bounded", 0.0 < mass <= 1.0 + 1e-9, "mass %r" % mass)
    check("ppr.source_positive", rank[src] >= 1.0 - DAMPING - 1e-12)
    # isolated star: all mass stays between hub and leaves
    star = [(0, i) for i in range(1, 5)]
    r = ppr_push(5, star, 0, PR_ROUNDS)
    check("ppr.star_hub_dominates", r[0] > max(r[1:]) > 0.0)
    unreach = ppr_push(5, star, 1, 1)
    check("ppr.leaf_sink", unreach[0] == 0.0 and unreach[1] == 1.0 - DAMPING)


# ---------------------------------------------------------------------------
# 6. [--totem] live runs vs the mirrors
# ---------------------------------------------------------------------------


def parse_dump_u64(path, n):
    got = [None] * n
    with open(path) as f:
        for line in f:
            v, x = line.split()
            got[int(v)] = int(x, 16)
    return got


def parse_dump_f32(path, n):
    import struct

    got = [None] * n
    with open(path) as f:
        for line in f:
            v, x = line.split()
            got[int(v)] = struct.unpack("<f", int(x, 16).to_bytes(4, "little"))[0]
    return got


def run_ok(name, cmd):
    proc = subprocess.run(cmd, capture_output=True, text=True)
    check(name, proc.returncode == 0, proc.stderr[-2000:])
    return proc


def check_live(totem, scale):
    seed = 42
    n, edges = rmat_paper(scale, seed)
    with tempfile.TemporaryDirectory() as d:
        # triangle run: u64 dump must equal the Python oracle exactly
        dump = os.path.join(d, "tri.txt")
        run_ok("live.tri.exit0",
               [totem, "run", "--alg", "triangles", "--workload",
                "rmat%d" % scale, "--seed", str(seed), "--threads", "2",
                "--dump-output", dump])
        got = parse_dump_u64(dump, n)
        want = triangles_oriented(n, edges)
        check("live.tri.counts", got == want,
              "first diff at vertex %s" %
              next((v for v in range(n) if got[v] != want[v]), -1))

        # ppr serve replay: through admission, the batcher's skip, and the
        # per-source cache; f32 dumps vs float64 power iteration
        sources = [0, 3, 0, n - 1]  # repeated source 0 exercises the cache
        qfile = os.path.join(d, "queries.txt")
        with open(qfile, "w") as f:
            for s in sources:
                f.write("ppr %d\n" % s)
            f.write("bfs 0\n")  # a lane batch riding alongside
        sdump = os.path.join(d, "serve")
        run_ok("live.serve.exit0",
               [totem, "serve", "--workload", "rmat%d" % scale, "--seed",
                str(seed), "--queries", qfile, "--dump-dir", sdump,
                "--rounds", str(PR_ROUNDS), "--serve-workers", "1",
                "--threads", "2"])
        for i, s in enumerate(sources):
            got = parse_dump_f32(os.path.join(sdump, "q%04d_ppr.txt" % i), n)
            want = ppr_push(n, edges, s, PR_ROUNDS)
            bad = next(
                (v for v in range(n)
                 if abs(got[v] - want[v]) > 1e-5 + 1e-4 * abs(want[v])),
                None)
            check("live.serve.ppr_q%d_src%d" % (i, s), bad is None,
                  "vertex %s: %r vs float64 %r" %
                  (bad, got[bad] if bad is not None else 0,
                   want[bad] if bad is not None else 0))
        # the repeated source must be answered byte-identically (a cache
        # hit can only ever return the same ranks)
        with open(os.path.join(sdump, "q0000_ppr.txt")) as a, \
                open(os.path.join(sdump, "q0002_ppr.txt")) as b:
            check("live.serve.cache_identical", a.read() == b.read())


def check_live_big(totem):
    """RMAT18 smoke: too large to re-solve in Python, so check
    cross-configuration determinism (integer kernels may not move a bit)
    and structural invariants on the dumps."""
    scale, seed = 18, 7
    n = 1 << scale
    with tempfile.TemporaryDirectory() as d:
        dumps = []
        for label, extra in (
            ("2t-edge", ["--threads", "2", "--balance", "edge"]),
            ("4t-hub", ["--threads", "4", "--balance", "hub-split"]),
        ):
            dump = os.path.join(d, "tri-%s.txt" % label)
            run_ok("big.tri.%s.exit0" % label,
                   [totem, "run", "--alg", "triangles", "--workload",
                    "rmat%d" % scale, "--seed", str(seed),
                    "--dump-output", dump] + extra)
            dumps.append(dump)
        with open(dumps[0]) as a, open(dumps[1]) as b:
            check("big.tri.deterministic", a.read() == b.read())
        got = parse_dump_u64(dumps[0], n)
        total = sum(got)
        check("big.tri.mod3", total % 3 == 0 and total > 0,
              "total incident count %d" % total)

        # ppr serve at scale 18: mass and dominance invariants only
        qfile = os.path.join(d, "queries.txt")
        with open(qfile, "w") as f:
            f.write("ppr 0\nppr 0\n")
        sdump = os.path.join(d, "serve")
        run_ok("big.serve.exit0",
               [totem, "serve", "--workload", "rmat%d" % scale, "--seed",
                str(seed), "--queries", qfile, "--dump-dir", sdump,
                "--rounds", str(PR_ROUNDS), "--serve-workers", "1",
                "--threads", "4"])
        got = parse_dump_f32(os.path.join(sdump, "q0000_ppr.txt"), n)
        mass = sum(got)
        check("big.serve.mass", 0.0 < mass <= 1.0 + 1e-3, "mass %r" % mass)
        check("big.serve.source_floor", got[0] >= 1.0 - DAMPING - 1e-6)
        with open(os.path.join(sdump, "q0000_ppr.txt")) as a, \
                open(os.path.join(sdump, "q0001_ppr.txt")) as b:
            check("big.serve.cache_identical", a.read() == b.read())


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--totem", help="path to a built totem binary for live checks")
    ap.add_argument("--scale", type=int, default=10,
                    help="R-MAT scale for the exact live oracle diff")
    ap.add_argument("--big", action="store_true",
                    help="with --totem: add the RMAT18 smoke invariants")
    args = ap.parse_args()
    check_goldens()
    check_triangle_duality()
    check_peel_duality()
    check_lp_determinism()
    check_ppr_mass()
    if args.totem:
        check_live(args.totem, args.scale)
        if args.big:
            check_live_big(args.totem)
    else:
        print("skip live checks (--totem not given)")
    print("PASS %d checks" % len(_passed))


if __name__ == "__main__":
    main()
