#!/usr/bin/env python3
"""Pure-Python reader/writer for the `.tcsr` v2 container.

Mirrors `rust/src/graph/store.rs` byte for byte (the layout is canonical:
given (|V|, |E|, weighted) there is exactly one valid byte stream, so a
Python-written container must equal a Rust-written one). The machine-
readable contract lives in `tools/tcsr_v2_layout.json`; this module is the
executable form used by `tools/cross_check_ingest.py`.

Raises ValueError with the same message keywords as the Rust reader
("truncated", "not a totem", "corrupt header", "checksum mismatch",
"trailing", "non-zero padding") so corruption tests can assert either
implementation interchangeably.
"""

import struct

MAGIC = b"TOTEMCSR"
VERSION_V2 = 2
FLAG_WEIGHTED = 1
SEC_ROW, SEC_COL, SEC_WEIGHTS = 1, 2, 3
FIXED_HEADER_BYTES = 40
TABLE_ENTRY_BYTES = 32

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1


def fnv1a64(data, h=FNV_OFFSET):
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK64
    return h


def _align8(x):
    return (x + 7) & ~7


def layout_for(vcount, ecount, weighted):
    """The one valid layout for (|V|, |E|, weighted) — store.rs layout_for."""
    n_sections = 3 if weighted else 2
    header_bytes = FIXED_HEADER_BYTES + n_sections * TABLE_ENTRY_BYTES + 8
    specs = [(SEC_ROW, 8, vcount + 1), (SEC_COL, 4, ecount)]
    if weighted:
        specs.append((SEC_WEIGHTS, 4, ecount))
    off = header_bytes
    sections = []
    for kind, elem_bytes, elem_count in specs:
        off = _align8(off)
        byte_len = elem_count * elem_bytes
        sections.append(
            {
                "kind": kind,
                "elem_bytes": elem_bytes,
                "offset": off,
                "elem_count": elem_count,
                "byte_len": byte_len,
            }
        )
        off += byte_len
    return {"header_bytes": header_bytes, "sections": sections, "total_bytes": off}


def _pack_section(xs, elem_bytes, is_float):
    fmt = "<%d%s" % (len(xs), "f" if is_float else ("I" if elem_bytes == 4 else "Q"))
    return struct.pack(fmt, *xs)


def encode(row_offsets, col_indices, weights=None):
    """Serialize a CSR graph to canonical v2 bytes."""
    weighted = weights is not None
    vcount = len(row_offsets) - 1
    ecount = len(col_indices)
    assert row_offsets[0] == 0 and row_offsets[-1] == ecount
    lay = layout_for(vcount, ecount, weighted)
    payloads = [
        _pack_section(row_offsets, 8, False),
        _pack_section(col_indices, 4, False),
    ]
    if weighted:
        payloads.append(_pack_section(weights, 4, True))
    h = bytearray()
    h += MAGIC
    h += struct.pack("<II", VERSION_V2, FLAG_WEIGHTED if weighted else 0)
    h += struct.pack("<QQ", vcount, ecount)
    h += struct.pack("<II", len(lay["sections"]), 0)
    for s, p in zip(lay["sections"], payloads):
        h += struct.pack(
            "<IIQQQ",
            s["kind"],
            s["elem_bytes"],
            s["offset"],
            s["elem_count"],
            fnv1a64(p),
        )
    h += struct.pack("<Q", fnv1a64(bytes(h)))
    assert len(h) == lay["header_bytes"]
    out = bytearray(h)
    for s, p in zip(lay["sections"], payloads):
        out += b"\x00" * (s["offset"] - len(out))  # alignment padding
        out += p
    assert len(out) == lay["total_bytes"]
    return bytes(out)


def write_tcsr(path, row_offsets, col_indices, weights=None):
    data = encode(row_offsets, col_indices, weights)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def decode(data, verify=True):
    """Parse + fully validate v2 bytes → (row_offsets, col_indices, weights)."""
    if len(data) < FIXED_HEADER_BYTES:
        raise ValueError("truncated header")
    if data[0:8] != MAGIC:
        raise ValueError("not a totem CSR file")
    ver, flags = struct.unpack_from("<II", data, 8)
    if ver != VERSION_V2:
        raise ValueError("unsupported version %d" % ver)
    if flags & ~FLAG_WEIGHTED:
        raise ValueError("corrupt header (unknown flags %#x)" % flags)
    weighted = bool(flags & FLAG_WEIGHTED)
    vcount, ecount = struct.unpack_from("<QQ", data, 16)
    n_sections, reserved = struct.unpack_from("<II", data, 32)
    if reserved != 0:
        raise ValueError("corrupt header (reserved field != 0)")
    lay = layout_for(vcount, ecount, weighted)
    if n_sections != len(lay["sections"]):
        raise ValueError("corrupt header (section count mismatch)")
    if len(data) < lay["header_bytes"]:
        raise ValueError("truncated header")
    hdr_end = FIXED_HEADER_BYTES + n_sections * TABLE_ENTRY_BYTES
    (stored_fnv,) = struct.unpack_from("<Q", data, hdr_end)
    if fnv1a64(data[:hdr_end]) != stored_fnv:
        raise ValueError("corrupt header (checksum mismatch)")
    sums = []
    for i, want in enumerate(lay["sections"]):
        kind, elem_bytes, offset, elem_count, sec_fnv = struct.unpack_from(
            "<IIQQQ", data, FIXED_HEADER_BYTES + i * TABLE_ENTRY_BYTES
        )
        got = (kind, elem_bytes, offset, elem_count)
        if got != (want["kind"], want["elem_bytes"], want["offset"], want["elem_count"]):
            raise ValueError("corrupt header (section %d disagrees with canonical layout)" % i)
        sums.append(sec_fnv)
    if len(data) < lay["total_bytes"]:
        raise ValueError("truncated CSR file")
    if len(data) > lay["total_bytes"]:
        raise ValueError("%d trailing bytes after CSR payload" % (len(data) - lay["total_bytes"]))
    prev_end = lay["header_bytes"]
    arrays = []
    for s, sec_fnv in zip(lay["sections"], sums):
        if any(data[prev_end : s["offset"]]):
            raise ValueError("corrupt CSR file (non-zero padding at offset %d)" % prev_end)
        payload = data[s["offset"] : s["offset"] + s["byte_len"]]
        if verify and fnv1a64(payload) != sec_fnv:
            raise ValueError("corrupt section %d (checksum mismatch)" % s["kind"])
        is_float = s["kind"] == SEC_WEIGHTS
        fmt = "<%d%s" % (s["elem_count"], "f" if is_float else ("I" if s["elem_bytes"] == 4 else "Q"))
        arrays.append(list(struct.unpack(fmt, payload)))
        prev_end = s["offset"] + s["byte_len"]
    row_offsets, col_indices = arrays[0], arrays[1]
    weights = arrays[2] if weighted else None
    # CsrGraph::validate mirror
    if row_offsets[0] != 0 or row_offsets[-1] != ecount:
        raise ValueError("corrupt CSR: row offsets")
    if any(a > b for a, b in zip(row_offsets, row_offsets[1:])):
        raise ValueError("corrupt CSR: row_offsets not monotone")
    if any(c >= vcount for c in col_indices):
        raise ValueError("corrupt CSR: col index out of range")
    return row_offsets, col_indices, weights


def read_tcsr(path, verify=True):
    with open(path, "rb") as f:
        return decode(f.read(), verify=verify)
