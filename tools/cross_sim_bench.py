#!/usr/bin/env python3
"""Cross-simulated BENCH_scaling baseline (DESIGN.md §11).

The build container for this repository has no Rust toolchain (`cargo:
command not found`), so the committed `BENCH_scaling.json` baseline cannot
be measured here. This script produces it by *cross-simulation* instead:

- the graph is **bit-exact**: SplitMix64-seeded Xoshiro256**, Lemire
  `below`, `next_f64 = (u >> 11) * 2^-53`, and the paper-parameter R-MAT
  descent (a=0.57, b=0.19, c=0.19, avg degree 16, Fisher-Yates permuted)
  are ported line-for-line from `rust/src/util/rng.rs` and
  `rust/src/graph/generator.rs`;
- the partition layout is **bit-exact**: one host partition, members
  stable-sorted by descending out-degree (`Placement::DegreeDesc`, the
  `EngineConfig::host_only` default), CSR row offsets in placed order;
- the chunk plans are **bit-exact**: `ChunkPlan::{vertex,edge,hub_split}`
  ported from `rust/src/util/threadpool.rs`, including the hub-split
  engagement test and shard bounds;
- the per-superstep *state trajectory* replays each derived kernel
  (traversal push, monotone scatter, gather, sigma, fold-scatter) with the
  single-chunk (threads=1) execution order — the same trajectory the
  engine's bit-identity contract guarantees for outputs at any
  thread/balance setting;
- *time* is a declared cost model, not a measurement: a superstep costs
  `max over chunks (C_V * vertices_scanned + C_E * edges_expanded)` plus
  sequential sweeps at `C_V`/`C_E` and a fixed dispatch overhead `C_D`,
  with C_E = 1.0 ns, C_V = 0.3 ns, C_D = 2 us.  Absolute TEPS are model
  units; the *relative* ordering across balance modes and thread counts is
  the signal.  CI's advisory bench-smoke job regenerates the measured
  artifact with `cargo bench --bench bench_scaling` whenever a toolchain
  is present.

Emits `BENCH_scaling.json` (repo root) and `results/bench_scaling.md`.
"""

import bisect
import json
import math
import os

MASK = (1 << 64) - 1
C_E = 1.0e-9  # per expanded/summed edge
C_V = 0.3e-9  # per scanned vertex (active test / publish / fold)
C_D = 2.0e-6  # per-superstep dispatch + barrier overhead

# ---------------------------------------------------------------------------
# rng.rs mirror
# ---------------------------------------------------------------------------


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31))


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """Xoshiro256** seeded via SplitMix64 — mirrors util::rng::Rng."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, z = _splitmix64(sm)
            s.append(z)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, bound):
        return (self.next_u64() * bound) >> 64

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

    def permutation(self, n):
        p = list(range(n))
        self.shuffle(p)
        return p


# ---------------------------------------------------------------------------
# generator.rs mirror
# ---------------------------------------------------------------------------


def rmat_paper(scale, seed):
    """RMAT with (A,B,C)=(0.57,0.19,0.19), degree 16, permuted."""
    a, b, c = 0.57, 0.19, 0.19
    n = 1 << scale
    m = n * 16
    rng = Rng(seed)
    edges = []
    for _ in range(m):
        x = y = 0
        for level in range(scale - 1, -1, -1):
            r = rng.next_f64()
            bit = 1 << level
            if r < a:
                pass
            elif r < a + b:
                y |= bit
            elif r < a + b + c:
                x |= bit
            else:
                x |= bit
                y |= bit
        edges.append((x, y))
    perm = rng.permutation(n)
    return n, [(perm[s], perm[d]) for (s, d) in edges]


def random_weights(m, max_w, seed):
    rng = Rng(seed)
    return [float(1 + rng.below(max_w)) for _ in range(m)]


class Csr:
    """Counting-sort CSR build: per-row targets keep edge-list order."""

    def __init__(self, n, edges, weights=None):
        self.n = n
        deg = [0] * n
        for s, _ in edges:
            deg[s] += 1
        off = [0] * (n + 1)
        for v in range(n):
            off[v + 1] = off[v] + deg[v]
        tgt = [0] * len(edges)
        wgt = [0.0] * len(edges) if weights is not None else None
        cur = off[:n]
        for k, (s, d) in enumerate(edges):
            tgt[cur[s]] = d
            if wgt is not None:
                wgt[cur[s]] = weights[k]
            cur[s] += 1
        self.off, self.tgt, self.wgt, self.deg = off, tgt, wgt, deg

    def targets(self, v):
        return self.tgt[self.off[v]:self.off[v + 1]]

    def wrange(self, v):
        return self.wgt[self.off[v]:self.off[v + 1]]


# ---------------------------------------------------------------------------
# threadpool.rs ChunkPlan mirror
# ---------------------------------------------------------------------------


class Plan:
    def __init__(self, chunks, hub, n):
        self.chunks = chunks  # list of (lo, hi, split)
        self.hub = hub
        self.n = n


def plan_single(n):
    return Plan([(0, n, None)], None, n)


def plan_vertex(n, threads):
    threads = max(threads, 1)
    if threads == 1 or n < 2 * threads:
        return plan_single(n)
    chunk = -(-n // threads)
    chunks = []
    for t in range(threads):
        lo, hi = t * chunk, min((t + 1) * chunk, n)
        if lo >= hi:
            break
        chunks.append((lo, hi, None))
    return Plan(chunks, None, n)


def plan_edge(row_offsets, threads):
    n = len(row_offsets) - 1
    threads = max(threads, 1)
    if threads == 1 or n < 2 * threads:
        return plan_single(n)
    base = row_offsets[0]
    total = row_offsets[n] - base
    if total == 0:
        return plan_vertex(n, threads)
    bounds = [0] * (threads + 1)
    bounds[threads] = n
    for t in range(1, threads):
        target = base + (total * t) // threads
        idx = min(bisect.bisect_left(row_offsets, target), n)
        bounds[t] = max(idx, bounds[t - 1])
    chunks = []
    for t in range(threads):
        lo, hi = bounds[t], bounds[t + 1]
        if lo < hi:
            chunks.append((lo, hi, None))
    return Plan(chunks, None, n)


def plan_hub_split(row_offsets, threads):
    n = len(row_offsets) - 1
    threads = max(threads, 1)
    if threads == 1 or n < 2 * threads:
        return plan_single(n)
    total = row_offsets[n] - row_offsets[0]
    if total == 0:
        return plan_vertex(n, threads)
    hub, deg_h = 0, 0
    for v in range(n):
        d = row_offsets[v + 1] - row_offsets[v]
        if d > deg_h:
            hub, deg_h = v, d
    if deg_h * threads <= total:
        return plan_edge(row_offsets, threads)
    rest = total - deg_h
    bounds = [0] * (threads + 1)
    bounds[threads] = n
    acc, t = 0, 1
    for v in range(n):
        if v != hub:
            acc += row_offsets[v + 1] - row_offsets[v]
        while t < threads and acc * threads >= rest * t:
            bounds[t] = v + 1
            t += 1
    chunks = []
    for t in range(threads):
        lo, hi = bounds[t], bounds[t + 1]
        e_lo, e_hi = deg_h * t // threads, deg_h * (t + 1) // threads
        split = (e_lo, e_hi) if e_lo < e_hi else None
        if lo < hi or split is not None:
            chunks.append((lo, hi, split))
    return Plan(chunks, hub, n)


def plan_for(balance, row_offsets, threads):
    if balance == "vertex":
        return plan_vertex(len(row_offsets) - 1, threads)
    if balance == "edge":
        return plan_edge(row_offsets, threads)
    return plan_hub_split(row_offsets, threads)


def edge_capped(balance):
    """ProgramDriver::edge_capped_plan: pull/gather degrade HubSplit→Edge."""
    return "edge" if balance == "hub-split" else balance


# ---------------------------------------------------------------------------
# Partition layout mirror (host_only + Placement::DegreeDesc)
# ---------------------------------------------------------------------------


def degree_desc_partition(g):
    """local_to_global: stable sort by descending out-degree."""
    order = sorted(range(g.n), key=lambda v: -g.deg[v])
    return order


def local_csr(g, order):
    """Partition-local CSR in placed order (single partition: all local)."""
    g2l = [0] * g.n
    for l, gv in enumerate(order):
        g2l[gv] = l
    edges = []
    weights = [] if g.wgt is not None else None
    for l, gv in enumerate(order):
        for k, t in enumerate(g.targets(gv)):
            edges.append((l, g2l[t]))
            if weights is not None:
                weights.append(g.wrange(gv)[k])
    return Csr(g.n, edges, weights)


# ---------------------------------------------------------------------------
# Per-algorithm superstep trajectories (threads=1 execution order)
# ---------------------------------------------------------------------------
# Each returns (supersteps, steps) where steps is a list of superstep
# descriptors:
#   ("par", {local_v: edges_expanded}, kind)  parallel kernel superstep;
#       kind "scatter" uses the scatter plan (HubSplit allowed),
#       kind "capped" uses the edge-capped plan;
#   ("seq", total_vertex_scans, total_edges)  sequential single-chunk step.
# Every parallel step also implicitly scans all nv vertices (active test).

INF = float("inf")
INF_I32 = 2**31 - 1


def traj_bfs(p, src):
    level = [INF_I32] * p.n
    level[src] = 0
    steps = []
    s = 0
    while True:
        active = {}
        discovered = []
        for v in range(p.n):
            if level[v] != s:
                continue
            active[v] = len(p.targets(v))
            for t in p.targets(v):
                if level[t] == INF_I32:
                    level[t] = s + 1
                    discovered.append(t)
        steps.append(("par", active, "scatter"))
        s += 1
        if not discovered:
            break
    return steps, level


def traj_monotone(p, init, relax, upward):
    """Shadow-gated monotone scatter, sequential in local-id order."""
    val = list(init)
    shadow = [(-INF if upward else INF)] * p.n
    steps = []
    while True:
        active = {}
        changed = False
        for v in range(p.n):
            dv = val[v]
            if (not upward and dv >= shadow[v]) or (upward and dv <= shadow[v]):
                continue
            shadow[v] = dv
            active[v] = len(p.targets(v))
            for k, t in enumerate(p.targets(v)):
                msg = relax(dv, p.wrange(v)[k] if p.wgt is not None else 0.0)
                if (not upward and msg < val[t]) or (upward and msg > val[t]):
                    val[t] = msg
                    changed = True
        steps.append(("par", active, "scatter"))
        if not changed:
            break
    return steps, val


def traj_gather_rounds(p, rounds):
    """Gather with Activation::Always for a fixed round count (PR pull)."""
    steps = []
    for _ in range(rounds):
        active = {v: len(p.targets(v)) for v in range(p.n)}
        steps.append(("par", active, "capped"))
    return steps


def traj_bc(p):
    """Two cycles: sequential sigma forward, edge-capped gather backward."""
    # forward: BFS levels + path counts, sequential canonical sweep
    # (single chunk regardless of balance — kind "seq").
    src = max(range(p.n), key=lambda v: (len(p.targets(v)), v))
    dist = [INF_I32] * p.n
    numsp = [0.0] * p.n
    dist[src] = 0
    numsp[src] = 1.0
    steps = []
    cur = 0
    while True:
        changed = False
        edges = 0
        for v in range(p.n):
            if dist[v] != cur:
                continue
            edges += len(p.targets(v))
            for t in p.targets(v):
                if dist[t] > cur + 1:
                    dist[t] = cur + 1
                    changed = True
                if dist[t] == cur + 1:
                    numsp[t] += numsp[v]
                    changed = True
        steps.append(("seq", p.n, edges))
        cur += 1
        if not changed:
            break
    max_level = max((d for d in dist if d != INF_I32), default=0)
    # backward: gather ratio over out-edges, active at dist == cur
    ratio = [0.0] * p.n
    bc = [0.0] * p.n
    for v in range(p.n):
        if dist[v] == max_level and numsp[v] > 0.0:
            ratio[v] = 1.0 / numsp[v]
    back = max(max_level - 1, 1)
    for s in range(back):
        lvl = max_level - 1 - s
        if lvl < 1:  # skip_superstep: engine-mandated no-op
            steps.append(("seq", 0, 0))
            continue
        active = {}
        delta = [0.0] * p.n
        for v in range(p.n):
            if dist[v] != lvl:
                continue
            active[v] = len(p.targets(v))
            sm = sum(ratio[t] for t in p.targets(v))
            delta[v] = numsp[v] * sm
            bc[v] += delta[v]
        steps.append(("par", active, "capped"))
        for v in range(p.n):
            if dist[v] == lvl and numsp[v] > 0.0:
                ratio[v] = (1.0 + delta[v]) / numsp[v]
            else:
                ratio[v] = 0.0
    return steps, dist, bc


# ---------------------------------------------------------------------------
# Cost model over a trajectory
# ---------------------------------------------------------------------------


def cost(steps, part, balance, threads):
    """(makespan_secs, chunk_spread_secs) for one trajectory/config."""
    scatter_plan = plan_for(balance, part.off, threads)
    capped_plan = plan_for(edge_capped(balance), part.off, threads)
    makespan = 0.0
    spread = 0.0
    for step in steps:
        if step[0] == "seq":
            _, scans, edges = step
            makespan += scans * C_V + edges * C_E + C_D
            continue
        _, active, kind = step
        plan = scatter_plan if kind == "scatter" else capped_plan
        loads = []
        for (lo, hi, split) in plan.chunks:
            load = (hi - lo) * C_V
            if split is not None and plan.hub in active:
                e_lo, e_hi = split
                load += (e_hi - e_lo) * C_E
            loads.append(load)
        # non-hub active vertices: bisect into contiguous chunk ranges
        bounds = [c[0] for c in plan.chunks]
        for v, deg in active.items():
            if v == plan.hub:
                continue
            i = bisect.bisect_right(bounds, v) - 1
            loads[i] += deg * C_E
        if kind == "capped":  # gather kernels add the sequential publish sweep
            makespan += plan.n * C_V
        makespan += max(loads) + C_D
        if len(loads) > 1:
            spread += max(loads) - min(loads)
    return makespan, spread


# ---------------------------------------------------------------------------
# Harness mirror
# ---------------------------------------------------------------------------


def resolve_source(g):
    """max_by_key(out_degree): Rust returns the LAST maximal element."""
    best, best_d = 0, -1
    for v in range(g.n):
        if g.deg[v] >= best_d:
            best, best_d = v, g.deg[v]
    return best


def build_alg(alg, scale, seed):
    """Returns (part, steps, traversed, supersteps) for one alg × scale."""
    n, edges = rmat_paper(scale, seed)
    weights = None
    if alg in ("sssp", "widest"):
        weights = random_weights(len(edges), 64, seed ^ 0x5EED)
    g = Csr(n, edges, weights)
    src = resolve_source(g)

    if alg == "cc":
        und = [e for (s, d) in edges for e in ((s, d), (d, s))]
        prepared = Csr(n, und)
    elif alg == "pagerank":  # pull mode partitions the reversed graph
        prepared = Csr(n, [(d, s) for (s, d) in edges])
    else:
        prepared = g

    order = degree_desc_partition(prepared)
    part = local_csr(prepared, order)
    g2l = [0] * n
    for l, gv in enumerate(order):
        g2l[gv] = l

    if alg == "bfs":
        steps, level = traj_bfs(part, g2l[src])
        traversed = sum(g.deg[v] for v in range(n) if level[g2l[v]] != INF_I32)
    elif alg == "cc":
        init = [order[l] for l in range(n)]  # label = global id
        steps, _ = traj_monotone(part, init, lambda dv, w: dv, upward=False)
        traversed = 2 * len(edges)
    elif alg == "sssp":
        init = [INF] * n
        init[g2l[src]] = 0.0
        steps, dist = traj_monotone(part, init, lambda dv, w: dv + w, upward=False)
        traversed = sum(g.deg[v] for v in range(n) if math.isfinite(dist[g2l[v]]))
    elif alg == "widest":
        init = [-INF] * n
        init[g2l[src]] = INF
        steps, width = traj_monotone(
            part, init, lambda dv, w: min(dv, w), upward=True
        )
        traversed = sum(g.deg[v] for v in range(n) if width[g2l[v]] > -INF)
    elif alg == "pagerank":
        steps = traj_gather_rounds(part, 5)
        traversed = len(edges) * 5
    elif alg == "bc":
        steps, dist, bc = traj_bc(part)
        traversed = 2 * sum(
            g.deg[order[l]] for l in range(n) if bc[l] > 0.0
        )
    else:
        raise ValueError(alg)
    return part, steps, traversed, len(steps)


def main():
    seed = 42
    scales = [12, 13]
    threads = [1, 2, 4]
    balances = ["vertex", "edge", "hub-split"]
    algs = ["bfs", "sssp", "cc", "widest", "pagerank", "bc"]

    rows = []
    md = []
    for alg in algs:
        for scale in scales:
            part, steps, traversed, supersteps = build_alg(alg, scale, seed)
            md.append(f"### BENCH_scaling: {alg} on RMAT{scale} (seed {seed})\n")
            md.append("| threads | vertex | edge | hub-split |")
            md.append("|---|---|---|---|")
            for th in threads:
                cells = [str(th)]
                for bal in balances:
                    mk, spread = cost(steps, part, bal, th)
                    teps = traversed / mk
                    cells.append(f"{teps / 1e6:.1f} MTEPS")
                    rows.append(
                        {
                            "alg": alg,
                            "scale": scale,
                            "threads": th,
                            "balance": bal,
                            "teps": teps,
                            "makespan_secs": mk,
                            "chunk_spread_secs": spread,
                            "supersteps": supersteps,
                        }
                    )
                md.append("| " + " | ".join(cells) + " |")
            md.append("")

    doc = {
        "bench": "BENCH_scaling",
        "workloads": "paper-parameter R-MAT (a=0.57 b=0.19 c=0.19, avg degree 16, permuted)",
        "seed": seed,
        "methodology": (
            "cross-simulated: the build container has no Rust toolchain "
            "(cargo: command not found), so this committed baseline was "
            "produced by tools/cross_sim_bench.py — graph generation "
            "(util::rng, graph::generator), DegreeDesc placement, and "
            "ChunkPlan::{vertex,edge,hub_split} boundaries are mirrored "
            "bit-exactly; per-superstep state trajectories replay the "
            "derived kernels in threads=1 order; time is a declared cost "
            "model (C_E=1.0ns/edge, C_V=0.3ns/vertex-scan, C_D=2us/superstep "
            "dispatch), so absolute TEPS are model units and the relative "
            "ordering across balance modes and thread counts is the signal. "
            "CI's bench-smoke job regenerates the measured artifact via "
            "`cargo bench --bench bench_scaling` when a toolchain exists."
        ),
        "rows": rows,
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_scaling.json"), "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.makedirs(os.path.join(root, "results"), exist_ok=True)
    with open(os.path.join(root, "results", "bench_scaling.md"), "w") as f:
        f.write(
            "# BENCH_scaling (cross-simulated baseline)\n\n"
            "See the methodology field in BENCH_scaling.json — model units, "
            "regenerated as a measured artifact by CI's bench-smoke job.\n\n"
        )
        f.write("\n".join(md))
        f.write("\n")
    print("\n".join(md))

    # Acceptance self-check: on skewed R-MATs at threads > 1, edge and
    # hub-split rows must meet or beat vertex TEPS.
    bad = []
    by_key = {
        (r["alg"], r["scale"], r["threads"], r["balance"]): r["teps"] for r in rows
    }
    for (alg, scale, th, bal), teps in by_key.items():
        if th == 1 or bal == "vertex":
            continue
        v = by_key[(alg, scale, th, "vertex")]
        if teps < v * 0.999:
            bad.append((alg, scale, th, bal, teps, v))
    if bad:
        print("WARNING: balance expectation violated:", bad)
    else:
        print("OK: edge/hub-split >= vertex TEPS on every threads>1 row")


if __name__ == "__main__":
    main()
