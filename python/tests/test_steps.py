"""L2 superstep correctness: model.PROGRAMS step functions vs oracles,
including multi-step convergence to whole-algorithm results on random
graphs (the padded-partition path the Rust engine exercises)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

INF = model.INF_I32


def _np(x):
    return np.asarray(x)


@st.composite
def coo_graph(draw):
    """Random padded COO 'partition': n includes a dummy sink at n-1."""
    n = draw(st.sampled_from([4, 8, 32, 65]))
    e = draw(st.sampled_from([8, 32, 128]))
    n_real = n - 1
    src = draw(st.lists(st.integers(0, n_real - 1), min_size=e, max_size=e))
    dst = draw(st.lists(st.integers(0, n_real - 1), min_size=e, max_size=e))
    n_pad = draw(st.integers(0, 8))
    src += [n - 1] * n_pad
    dst += [n - 1] * n_pad
    return n, np.array(src, np.int32), np.array(dst, np.int32)


@settings(max_examples=15, deadline=None)
@given(g=coo_graph(), cur=st.integers(0, 3))
def test_bfs_step_matches_ref(g, cur):
    n, src, dst = g
    rng = np.random.default_rng(len(src))
    levels = rng.choice([0, 1, 2, 3, INF], size=n).astype(np.int32)
    levels[n - 1] = INF  # dummy
    step = model.make_bfs_step()
    out, changed = step(jnp.array(levels), jnp.array(src), jnp.array(dst),
                        jnp.array([cur], jnp.int32))
    exp, exp_changed = ref.bfs_step_ref(levels, src, dst, cur)
    np.testing.assert_array_equal(_np(out), exp)
    assert int(_np(changed)[0]) == exp_changed


@settings(max_examples=15, deadline=None)
@given(g=coo_graph())
def test_sssp_step_matches_ref(g):
    n, src, dst = g
    rng = np.random.default_rng(len(src) + 1)
    dist = rng.choice([0.0, 1.5, 3.0, np.inf], size=n).astype(np.float32)
    w = rng.uniform(0.5, 4.0, size=len(src)).astype(np.float32)
    step = model.make_sssp_step()
    out, changed = step(jnp.array(dist), jnp.array(src), jnp.array(dst), jnp.array(w))
    exp, exp_changed = ref.sssp_step_ref(dist, src, dst, w)
    np.testing.assert_allclose(_np(out), exp, rtol=1e-6)
    assert int(_np(changed)[0]) == exp_changed


@settings(max_examples=15, deadline=None)
@given(g=coo_graph())
def test_widest_step_matches_ref(g):
    n, src, dst = g
    rng = np.random.default_rng(len(src) + 7)
    width = rng.choice([-np.inf, 1.0, 2.5, np.inf], size=n).astype(np.float32)
    width[n - 1] = -np.inf  # dummy sink holds the max identity
    w = rng.uniform(0.5, 4.0, size=len(src)).astype(np.float32)
    step = model.make_widest_step()
    out, changed = step(jnp.array(width), jnp.array(src), jnp.array(dst), jnp.array(w))
    exp, exp_changed = ref.widest_step_ref(width, src, dst, w)
    np.testing.assert_allclose(_np(out), exp, rtol=0, atol=0)
    assert int(_np(changed)[0]) == exp_changed


@settings(max_examples=15, deadline=None)
@given(g=coo_graph())
def test_cc_step_matches_ref(g):
    n, src, dst = g
    rng = np.random.default_rng(len(src) + 2)
    labels = rng.integers(0, n, size=n).astype(np.int32)
    step = model.make_cc_step()
    out, changed = step(jnp.array(labels), jnp.array(src), jnp.array(dst))
    exp, exp_changed = ref.cc_step_ref(labels, src, dst)
    np.testing.assert_array_equal(_np(out), exp)
    assert int(_np(changed)[0]) == exp_changed


@settings(max_examples=12, deadline=None)
@given(g=coo_graph())
def test_pagerank_step_matches_ref(g):
    n, src, dst = g
    rng = np.random.default_rng(len(src) + 3)
    rank = rng.uniform(0, 1, n).astype(np.float32)
    contrib = rng.uniform(0, 1, n).astype(np.float32)
    inv_outdeg = rng.uniform(0, 1, n).astype(np.float32)
    mask = (rng.uniform(0, 1, n) > 0.3).astype(np.float32)
    mask[n - 1] = 0.0
    base, damping = np.float32(0.15 / n), np.float32(0.85)
    step = model.make_pagerank_step()
    r, c, _ = step(jnp.array(rank), jnp.array(contrib), jnp.array(inv_outdeg),
                   jnp.array(mask), jnp.array(src), jnp.array(dst),
                   jnp.array([base, damping], jnp.float32))
    er, ec, _ = ref.pagerank_step_ref(rank, contrib, inv_outdeg, mask, src, dst,
                                      base, damping)
    np.testing.assert_allclose(_np(r), er, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(_np(c), ec, rtol=1e-5, atol=1e-7)


@settings(max_examples=12, deadline=None)
@given(g=coo_graph(), cur=st.integers(0, 2))
def test_bc_fwd_step_matches_ref(g, cur):
    n, src, dst = g
    rng = np.random.default_rng(len(src) + 4)
    dist = rng.choice([0, 1, 2, INF], size=n).astype(np.int32)
    dist[n - 1] = INF
    numsp = np.where(dist != INF, rng.integers(1, 4, n), 0).astype(np.float32)
    step = model.make_bc_fwd_step()
    d, s, changed = step(jnp.array(dist), jnp.array(numsp), jnp.array(src),
                         jnp.array(dst), jnp.array([cur], jnp.int32))
    ed, es, ec = ref.bc_fwd_step_ref(dist, numsp, src, dst, cur)
    np.testing.assert_array_equal(_np(d), ed)
    np.testing.assert_allclose(_np(s), es, rtol=1e-5)
    assert int(_np(changed)[0]) == ec


@settings(max_examples=12, deadline=None)
@given(g=coo_graph(), cur=st.integers(0, 2))
def test_bc_bwd_step_matches_ref(g, cur):
    n, src, dst = g
    rng = np.random.default_rng(len(src) + 5)
    dist = rng.choice([0, 1, 2, 3, INF], size=n).astype(np.int32)
    dist[n - 1] = INF
    numsp = np.where(dist != INF, rng.integers(1, 4, n), 0).astype(np.float32)
    delta = rng.uniform(0, 2, n).astype(np.float32)
    bc = rng.uniform(0, 2, n).astype(np.float32)
    ratio = np.where(dist == cur + 1, rng.uniform(0.1, 1, n), 0).astype(np.float32)
    step = model.make_bc_bwd_step()
    d2, s2, dl, b2, r2, _ = step(
        jnp.array(dist), jnp.array(numsp), jnp.array(delta), jnp.array(bc),
        jnp.array(ratio), jnp.array(src), jnp.array(dst),
        jnp.array([cur], jnp.int32))
    edl, eb, er, _ = ref.bc_bwd_step_ref(dist, numsp, delta, bc, ratio, src, dst, cur)
    np.testing.assert_array_equal(_np(d2), dist)
    np.testing.assert_array_equal(_np(s2), numsp)
    np.testing.assert_allclose(_np(dl), edl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(_np(b2), eb, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(_np(r2), er, rtol=1e-5, atol=1e-6)


# --- multi-step convergence on random graphs -------------------------------

def _random_graph(seed, n_real=40, e=160):
    rng = np.random.default_rng(seed)
    n = n_real + 1  # + dummy
    src = rng.integers(0, n_real, e).astype(np.int32)
    dst = rng.integers(0, n_real, e).astype(np.int32)
    return n, src, dst


def test_bfs_converges_to_full_traversal():
    n, src, dst = _random_graph(11)
    step = model.make_bfs_step()
    levels = np.full(n, INF, np.int32)
    levels[0] = 0
    cur = 0
    for _ in range(n):
        out, changed = step(jnp.array(levels), jnp.array(src), jnp.array(dst),
                            jnp.array([cur], jnp.int32))
        levels = _np(out)
        cur += 1
        if int(_np(changed)[0]) == 0:
            break
    np.testing.assert_array_equal(levels, ref.bfs_full_ref(n, src, dst, 0))


def test_sssp_converges_to_shortest_paths():
    n, src, dst = _random_graph(13)
    rng = np.random.default_rng(99)
    w = rng.uniform(0.5, 3.0, len(src)).astype(np.float32)
    step = model.make_sssp_step()
    dist = np.full(n, np.inf, np.float32)
    dist[0] = 0.0
    for _ in range(n + 1):
        out, changed = step(jnp.array(dist), jnp.array(src), jnp.array(dst), jnp.array(w))
        if int(_np(changed)[0]) == 0:
            break
        dist = _np(out)
    np.testing.assert_allclose(dist, ref.sssp_full_ref(n, src, dst, w, 0), rtol=1e-5)
