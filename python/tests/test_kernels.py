"""L1 kernel correctness: Pallas scatter primitives vs sequential oracles.

Hypothesis sweeps shapes, dtypes, index patterns (duplicates, dummy-slot
padding) — the CORE correctness signal for the accelerator path.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.scatter_ops import (
    edge_scatter_add,
    edge_scatter_add_jnp,
    edge_scatter_max,
    edge_scatter_max_jnp,
    edge_scatter_min,
    edge_scatter_min_jnp,
)


def _np(x):
    return np.asarray(x)


@st.composite
def scatter_case(draw, value_dtype):
    n = draw(st.sampled_from([1, 2, 8, 17, 64, 256]))
    e = draw(st.sampled_from([1, 8, 64, 128, 512]))
    idx = draw(
        st.lists(st.integers(0, n - 1), min_size=e, max_size=e)
    )
    if value_dtype == "i32":
        base = draw(st.lists(st.integers(-1000, 1000), min_size=n, max_size=n))
        val = draw(st.lists(st.integers(-1000, 1000), min_size=e, max_size=e))
        return (
            np.array(base, np.int32),
            np.array(idx, np.int32),
            np.array(val, np.int32),
        )
    base = draw(
        st.lists(
            st.floats(-1e3, 1e3, allow_nan=False, allow_subnormal=False, width=32), min_size=n, max_size=n
        )
    )
    val = draw(
        st.lists(
            st.floats(-1e3, 1e3, allow_nan=False, allow_subnormal=False, width=32), min_size=e, max_size=e
        )
    )
    return (
        np.array(base, np.float32),
        np.array(idx, np.int32),
        np.array(val, np.float32),
    )


@settings(max_examples=25, deadline=None)
@given(case=scatter_case("i32"))
def test_scatter_min_i32_matches_ref(case):
    base, idx, val = case
    out = _np(edge_scatter_min(jnp.array(base), jnp.array(idx), jnp.array(val)))
    np.testing.assert_array_equal(out, ref.scatter_min_ref(base, idx, val))


@settings(max_examples=25, deadline=None)
@given(case=scatter_case("f32"))
def test_scatter_min_f32_matches_ref(case):
    base, idx, val = case
    out = _np(edge_scatter_min(jnp.array(base), jnp.array(idx), jnp.array(val)))
    # atol=0 allclose: IEEE minimum(-0.0, 0.0) = -0.0, the `<` oracle keeps
    # +0.0 — numerically identical, bitwise not.
    np.testing.assert_allclose(out, ref.scatter_min_ref(base, idx, val), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(case=scatter_case("f32"))
def test_scatter_max_f32_matches_ref(case):
    base, idx, val = case
    out = _np(edge_scatter_max(jnp.array(base), jnp.array(idx), jnp.array(val)))
    # atol=0 allclose (not array_equal): IEEE maximum(-0.0, 0.0) vs the `>`
    # oracle can differ on the sign of zero — numerically identical.
    np.testing.assert_allclose(out, ref.scatter_max_ref(base, idx, val), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(case=scatter_case("f32"))
def test_scatter_max_pallas_matches_jnp_variant(case):
    base, idx, val = case
    a = _np(edge_scatter_max(jnp.array(base), jnp.array(idx), jnp.array(val)))
    b = _np(edge_scatter_max_jnp(jnp.array(base), jnp.array(idx), jnp.array(val)))
    np.testing.assert_array_equal(a, b)


@st.composite
def scatter_add_case(draw):
    """f32 add values as multiples of 0.5: sums stay exactly representable,
    so the result is order-independent and comparable bit-exactly."""
    n = draw(st.sampled_from([1, 2, 8, 17, 64, 256]))
    e = draw(st.sampled_from([1, 8, 64, 128, 512]))
    idx = draw(st.lists(st.integers(0, n - 1), min_size=e, max_size=e))
    base = draw(st.lists(st.integers(-64, 64), min_size=n, max_size=n))
    val = draw(st.lists(st.integers(-64, 64), min_size=e, max_size=e))
    return (
        (np.array(base, np.float32) / 2.0).astype(np.float32),
        np.array(idx, np.int32),
        (np.array(val, np.float32) / 2.0).astype(np.float32),
    )


@settings(max_examples=25, deadline=None)
@given(case=scatter_add_case())
def test_scatter_add_f32_matches_ref(case):
    base, idx, val = case
    out = _np(edge_scatter_add(jnp.array(base), jnp.array(idx), jnp.array(val)))
    np.testing.assert_array_equal(out, ref.scatter_add_ref(base, idx, val))


@settings(max_examples=15, deadline=None)
@given(case=scatter_case("i32"))
def test_pallas_matches_jnp_variant(case):
    base, idx, val = case
    a = _np(edge_scatter_min(jnp.array(base), jnp.array(idx), jnp.array(val)))
    b = _np(edge_scatter_min_jnp(jnp.array(base), jnp.array(idx), jnp.array(val)))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("grid", [1, 2, 4, 8])
def test_grid_invariance(grid):
    """Result must not depend on the HBM->VMEM tiling."""
    rng = np.random.default_rng(42)
    n, e = 128, 1024
    base = rng.integers(0, 1000, n).astype(np.int32)
    idx = rng.integers(0, n, e).astype(np.int32)
    val = rng.integers(0, 1000, e).astype(np.int32)
    out = _np(edge_scatter_min(jnp.array(base), jnp.array(idx), jnp.array(val), grid=grid))
    np.testing.assert_array_equal(out, ref.scatter_min_ref(base, idx, val))


def test_add_grid_invariance():
    rng = np.random.default_rng(7)
    n, e = 64, 512
    base = rng.normal(size=n).astype(np.float32)
    idx = rng.integers(0, n, e).astype(np.int32)
    val = rng.normal(size=e).astype(np.float32)
    outs = [
        _np(edge_scatter_add(jnp.array(base), jnp.array(idx), jnp.array(val), grid=g))
        for g in (1, 4, 8)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5)


def test_dummy_slot_padding_is_inert():
    """Padding edges target slot n-1 with identity values — a no-op."""
    n = 16
    base = np.full(n, ref.INF_I32, np.int32)
    base[0] = 0
    idx = np.full(32, n - 1, np.int32)
    val = np.full(32, ref.INF_I32, np.int32)
    out = _np(edge_scatter_min(jnp.array(base), jnp.array(idx), jnp.array(val)))
    np.testing.assert_array_equal(out, base)

    basef = np.zeros(n, np.float32)
    valf = np.zeros(32, np.float32)
    outf = _np(edge_scatter_add(jnp.array(basef), jnp.array(idx), jnp.array(valf)))
    np.testing.assert_array_equal(outf, basef)


def test_duplicate_indices_reduce():
    base = np.full(4, 100, np.int32)
    idx = np.array([2, 2, 2, 2], np.int32)
    val = np.array([5, 9, 3, 7], np.int32)
    out = _np(edge_scatter_min(jnp.array(base), jnp.array(idx), jnp.array(val)))
    assert out[2] == 3
    outa = _np(
        edge_scatter_add(
            jnp.zeros(4, jnp.float32), jnp.array(idx), jnp.array(val, np.float32)
        )
    )
    assert outa[2] == 24.0
