"""Perf-pass L1 experiment: grid width of the Pallas scatter kernels.

Times the *compiled* BFS step (the same XLA pipeline the Rust PJRT runtime
executes) at several edge-tile grid widths plus the plain-jnp lowering, at
a representative size class. Run manually:

    python tests/perf_grid_sweep.py [n_cap] [e_cap]

Not collected by pytest (no `test_` prefix); results feed EXPERIMENTS.md
§Perf and the DEFAULT_GRID choice in kernels/scatter_ops.py.
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def bench(step, args, iters=20):
    out = step(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1 << 16
    e = int(sys.argv[2]) if len(sys.argv) > 2 else 1 << 19
    rng = np.random.default_rng(0)
    levels = jnp.array(
        np.where(rng.uniform(size=n) < 0.1, 1, model.INF_I32).astype(np.int32)
    )
    src = jnp.array(rng.integers(0, n - 1, e).astype(np.int32))
    dst = jnp.array(rng.integers(0, n - 1, e).astype(np.int32))
    cur = jnp.array([1], jnp.int32)

    print(f"n={n} e={e}")
    results = {}
    for grid in [1, 2, 4, 8, 16]:
        step = jax.jit(model.make_bfs_step(interpret=True, grid=grid))
        dt = bench(step, (levels, src, dst, cur))
        results[f"grid={grid}"] = dt
        print(f"  pallas grid={grid:<3} {dt*1e3:8.2f} ms/step  ({e/dt/1e6:7.1f} Medges/s)")
    step = jax.jit(model.make_bfs_step(use_pallas=False))
    dt = bench(step, (levels, src, dst, cur))
    results["jnp"] = dt
    print(f"  jnp (no pallas) {dt*1e3:8.2f} ms/step  ({e/dt/1e6:7.1f} Medges/s)")
    best = min(results, key=results.get)
    print(f"best: {best}")


if __name__ == "__main__":
    main()
