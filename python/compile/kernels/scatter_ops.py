"""L1 Pallas kernels: the per-edge scatter primitives.

The paper's GPU hot loop is "for each edge, atomically min/add into the
destination vertex's state" (Figures 11/14/18/20). On the TPU-shaped Pallas
model there are no per-thread atomics; the same computation is expressed as
**blocked segment scatter**: the edge stream is tiled across a grid via
``BlockSpec`` (the HBM->VMEM streaming schedule — the analogue of the
paper's coalesced edge reads), while the vertex-state array is the
VMEM-resident accumulator carried across grid steps. Conflicting updates
become an XLA ``scatter`` with a ``min``/``add`` combiner — an associative
reduction the compiler serializes safely, replacing ``atomicMin/atomicAdd``.

``interpret=True`` is mandatory on this CPU-only image (real TPU lowering
emits Mosaic custom-calls the CPU PJRT plugin cannot run); interpret-mode
pallas lowers to plain HLO, which is exactly what the Rust runtime loads.

VMEM working set per grid step (documented per size class in
EXPERIMENTS.md): ``4B x N_cap`` for the accumulator block plus
``(4B + 4B) x BLK_E`` for the edge tile.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Grid width. On a real TPU, grid > 1 is the HBM<->VMEM edge-tile pipeline
# (tile = e_cap/grid edges streamed against the VMEM-resident accumulator).
# On this CPU PJRT backend every extra grid step pays an O(N) accumulator
# round-trip, so the perf pass (EXPERIMENTS.md §Perf-L1) measured
# grid 1/2/4/8/16: grid=1 runs 7.4x faster than the initial grid=8
# (308 vs 42 Medges/s at n=2^16, e=2^19) and 10% faster than the plain-jnp
# lowering. AOT artifacts therefore use grid=1; the gridded path stays
# exercised by the correctness tests and is the TPU deployment story.
DEFAULT_GRID = 1


def _pick_grid(n_edges: int, grid: int | None) -> int:
    if grid is not None:
        return grid
    g = DEFAULT_GRID
    while g > 1 and (n_edges % g != 0 or n_edges // g < 64):
        g //= 2
    return max(g, 1)


def _scatter_kernel(base_ref, idx_ref, val_ref, out_ref, *, op: str):
    """One grid step: fold an edge tile into the full-width accumulator."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = base_ref[...]

    acc = out_ref[...]
    idx = idx_ref[...]
    val = val_ref[...]
    if op == "min":
        out_ref[...] = acc.at[idx].min(val)
    elif op == "max":
        out_ref[...] = acc.at[idx].max(val)
    elif op == "add":
        out_ref[...] = acc.at[idx].add(val)
    else:  # pragma: no cover - guarded by the public wrappers
        raise ValueError(f"bad op {op}")


def _edge_scatter(base, idx, val, *, op: str, grid: int | None, interpret: bool):
    n = base.shape[0]
    e = idx.shape[0]
    g = _pick_grid(e, grid)
    blk = e // g
    assert blk * g == e, f"grid {g} must divide edge count {e}"
    return pl.pallas_call(
        partial(_scatter_kernel, op=op),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),    # accumulator: resident
            pl.BlockSpec((blk,), lambda i: (i,)),  # edge tile: streamed
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        interpret=interpret,
    )(base, idx, val)


def edge_scatter_min(base, idx, val, *, grid: int | None = None, interpret: bool = True):
    """``out[i] = min(base[i], min over {val[k] : idx[k] == i})``.

    The atomicMin of the paper's BFS/SSSP/CC/BC-forward kernels.
    """
    return _edge_scatter(base, idx, val, op="min", grid=grid, interpret=interpret)


def edge_scatter_max(base, idx, val, *, grid: int | None = None, interpret: bool = True):
    """``out[i] = max(base[i], max over {val[k] : idx[k] == i})``.

    The atomicMax dual of the min scatter — widest path's bottleneck
    relaxation. `-inf` is the identity, so dummy-sink padding edges stay
    inert.
    """
    return _edge_scatter(base, idx, val, op="max", grid=grid, interpret=interpret)


def edge_scatter_add(base, idx, val, *, grid: int | None = None, interpret: bool = True):
    """``out[i] = base[i] + sum over {val[k] : idx[k] == i}``.

    The atomicAdd of PageRank's rank aggregation and BC's sigma counting.
    """
    return _edge_scatter(base, idx, val, op="add", grid=grid, interpret=interpret)


# --- pure-jnp equivalents (ablation + the L2 "jnp" lowering variant) -------

def edge_scatter_min_jnp(base, idx, val):
    return base.at[idx].min(val)


def edge_scatter_max_jnp(base, idx, val):
    return base.at[idx].max(val)


def edge_scatter_add_jnp(base, idx, val):
    return base.at[idx].add(val)
