"""Pure-numpy correctness oracles.

Deliberately written as plain sequential loops (the most obviously-correct
form) so a shared bug with the vectorized kernels is impossible. pytest
compares both the L1 scatter primitives and the L2 superstep functions
against these.
"""

import numpy as np

INF_I32 = 1 << 30


def scatter_min_ref(base, idx, val):
    out = np.array(base, copy=True)
    for k in range(len(idx)):
        i = int(idx[k])
        if val[k] < out[i]:
            out[i] = val[k]
    return out


def scatter_max_ref(base, idx, val):
    out = np.array(base, copy=True)
    for k in range(len(idx)):
        i = int(idx[k])
        if val[k] > out[i]:
            out[i] = val[k]
    return out


def scatter_add_ref(base, idx, val):
    out = np.array(base, copy=True)
    for k in range(len(idx)):
        out[int(idx[k])] += val[k]
    return out


def bfs_step_ref(levels, src, dst, cur):
    """One level-synchronous BFS superstep over a COO edge list."""
    out = np.array(levels, copy=True)
    for k in range(len(src)):
        if levels[int(src[k])] == cur:
            cand = cur + 1
            if cand < out[int(dst[k])]:
                out[int(dst[k])] = cand
    changed = int(np.any(out != levels))
    return out, changed


def sssp_step_ref(dist, src, dst, w):
    """One all-edge Bellman-Ford relaxation."""
    out = np.array(dist, copy=True)
    for k in range(len(src)):
        cand = dist[int(src[k])] + w[k]
        if cand < out[int(dst[k])]:
            out[int(dst[k])] = cand
    changed = int(np.any(out != dist))
    return out, changed


def widest_step_ref(width, src, dst, w):
    """One all-edge widest-path (max-min) relaxation."""
    out = np.array(width, copy=True)
    for k in range(len(src)):
        cand = min(width[int(src[k])], w[k])
        if cand > out[int(dst[k])]:
            out[int(dst[k])] = cand
    changed = int(np.any(out != width))
    return out, changed


def cc_step_ref(labels, src, dst):
    """One label-propagation relaxation."""
    out = np.array(labels, copy=True)
    for k in range(len(src)):
        cand = labels[int(src[k])]
        if cand < out[int(dst[k])]:
            out[int(dst[k])] = cand
    changed = int(np.any(out != labels))
    return out, changed


def pagerank_step_ref(rank, contrib, inv_outdeg, mask, src, dst, base, damping):
    """One pull-based PageRank round: src indexes contributors."""
    sums = np.zeros_like(rank)
    for k in range(len(src)):
        sums[int(dst[k])] += contrib[int(src[k])]
    new_rank = np.where(mask > 0.5, base + damping * sums, rank)
    new_contrib = np.where(mask > 0.5, new_rank * inv_outdeg, contrib)
    return new_rank.astype(np.float32), new_contrib.astype(np.float32), 1


def bc_fwd_step_ref(dist, numsp, src, dst, cur):
    """One BC forward superstep: settle levels, then accumulate sigma."""
    new_dist = np.array(dist, copy=True)
    for k in range(len(src)):
        if dist[int(src[k])] == cur and cur + 1 < new_dist[int(dst[k])]:
            new_dist[int(dst[k])] = cur + 1
    new_numsp = np.array(numsp, copy=True)
    for k in range(len(src)):
        if dist[int(src[k])] == cur and new_dist[int(dst[k])] == cur + 1:
            new_numsp[int(dst[k])] += numsp[int(src[k])]
    changed = int(np.any(new_dist != dist) or np.any(new_numsp != numsp))
    return new_dist, new_numsp, changed


def bc_bwd_step_ref(dist, numsp, delta, bc, ratio, src, dst, cur):
    """One BC backward superstep over published ratios."""
    sums = np.zeros_like(ratio)
    for k in range(len(src)):
        sums[int(src[k])] += ratio[int(dst[k])]
    at = dist == cur
    new_delta = np.where(at, numsp * sums, delta).astype(np.float32)
    new_bc = (bc + np.where(at, new_delta, 0.0)).astype(np.float32)
    with np.errstate(divide="ignore", invalid="ignore"):
        r = np.where(at & (numsp > 0), (1.0 + new_delta) / np.maximum(numsp, 1e-30), 0.0)
    return new_delta, new_bc, r.astype(np.float32), 1


# --- tiny end-to-end oracles over a COO graph (multi-step convergence) -----

def bfs_full_ref(n, src, dst, source):
    levels = np.full(n, INF_I32, np.int32)
    levels[source] = 0
    cur = 0
    while True:
        levels2, changed = bfs_step_ref(levels, src, dst, cur)
        levels = levels2
        cur += 1
        if not changed:
            return levels


def sssp_full_ref(n, src, dst, w, source):
    dist = np.full(n, np.inf, np.float32)
    dist[source] = 0.0
    while True:
        dist2, changed = sssp_step_ref(dist, src, dst, w)
        if not changed:
            return dist
        dist = dist2
