"""L2: per-algorithm BSP superstep functions (build-time JAX).

Each function is one accelerator superstep over a padded partition, calling
the L1 Pallas scatter kernels. The marshaling contract with the Rust
runtime (``rust/src/runtime/``, see also DESIGN.md §3) is positional:

    inputs:  (state arrays [N]..., aux arrays [N]..., src [E] i32,
              dst [E] i32, [w [E] f32], [si32 [k]], [sf32 [k]])
    outputs: (state arrays [N]..., changed i32[1])

Conventions shared with the Rust engine:
- ``INF_I32 = 1 << 30`` marks unreached i32 levels (not i32::MAX, so +1
  cannot overflow); f32 distances use IEEE infinity (inf + w == inf keeps
  padding edges inert);
- device index ``N-1`` is the dummy sink: padding edges point there and
  its state is an identity element for every reduce, so they are no-ops;
- ghost slots live inside the state arrays; the Rust engine performs all
  inbox/outbox exchange host-side between supersteps.

``PROGRAMS`` is the registry ``aot.py`` lowers and ``manifest.json``
advertises to the Rust runtime.
"""

import jax.numpy as jnp

from .kernels import scatter_ops as k

INF_I32 = 1 << 30


def _changed_any(diff) -> jnp.ndarray:
    return jnp.any(diff).astype(jnp.int32).reshape((1,))


def make_bfs_step(interpret=True, grid=None, use_pallas=True):
    """Level-synchronous BFS relaxation (paper Figure 11)."""
    smin = k.edge_scatter_min if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_min_jnp(b, i, v)
    )

    def bfs_step(levels, src, dst, si32):
        cur = si32[0]
        cand = jnp.where(levels[src] == cur, cur + 1, jnp.int32(INF_I32))
        new = smin(levels, dst, cand, grid=grid, interpret=interpret)
        return new, _changed_any(new != levels)

    return bfs_step


def make_sssp_step(interpret=True, grid=None, use_pallas=True):
    """All-edge Bellman-Ford relaxation (paper Figure 20 / Harish et al.)."""
    smin = k.edge_scatter_min if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_min_jnp(b, i, v)
    )

    def sssp_step(dist, src, dst, w):
        cand = dist[src] + w  # inf + w == inf: padding edges are inert
        new = smin(dist, dst, cand, grid=grid, interpret=interpret)
        return new, _changed_any(new < dist)

    return sssp_step


def make_widest_step(interpret=True, grid=None, use_pallas=True):
    """All-edge widest-path (max-min bottleneck) relaxation — SSSP's dual.

    ``width`` starts at ``-inf`` (the max identity; the dummy sink stays
    there, so ``min(width[src], w)`` over a padding edge is ``-inf`` and
    inert), the source at ``+inf``.
    """
    smax = k.edge_scatter_max if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_max_jnp(b, i, v)
    )

    def widest_step(width, src, dst, w):
        cand = jnp.minimum(width[src], w)  # -inf stays -inf: padding inert
        new = smax(width, dst, cand, grid=grid, interpret=interpret)
        return new, _changed_any(new > width)

    return widest_step


def make_cc_step(interpret=True, grid=None, use_pallas=True):
    """Label-propagation relaxation over the undirected COO."""
    smin = k.edge_scatter_min if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_min_jnp(b, i, v)
    )

    def cc_step(labels, src, dst):
        cand = labels[src]
        new = smin(labels, dst, cand, grid=grid, interpret=interpret)
        return new, _changed_any(new != labels)

    return cc_step


def make_pagerank_step(interpret=True, grid=None, use_pallas=True):
    """Pull-based PageRank round (paper Figure 14).

    ``src`` indexes contributors (in-neighbors, possibly ghost-in slots),
    ``dst`` the ranked vertex. ``mask`` selects real local vertices: ghost
    slots must keep their pulled contributions and the rank of non-real
    slots is meaningless.
    """
    sadd = k.edge_scatter_add if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_add_jnp(b, i, v)
    )

    def pagerank_step(rank, contrib, inv_outdeg, mask, src, dst, sf32):
        base, damping = sf32[0], sf32[1]
        sums = sadd(jnp.zeros_like(rank), dst, contrib[src], grid=grid, interpret=interpret)
        real = mask > 0.5
        new_rank = jnp.where(real, base + damping * sums, rank)
        new_contrib = jnp.where(real, new_rank * inv_outdeg, contrib)
        return new_rank, new_contrib, jnp.ones((1,), jnp.int32)

    return pagerank_step


def make_bc_fwd_step(interpret=True, grid=None, use_pallas=True):
    """BC forward superstep (paper Figure 18 forwardPropagation):
    settle levels with min, then accumulate sigma into vertices that ended at
    exactly ``cur + 1``."""
    smin = k.edge_scatter_min if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_min_jnp(b, i, v)
    )
    sadd = k.edge_scatter_add if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_add_jnp(b, i, v)
    )

    def bc_fwd_step(dist, numsp, src, dst, si32):
        cur = si32[0]
        active = dist[src] == cur
        cand = jnp.where(active, cur + 1, jnp.int32(INF_I32))
        new_dist = smin(dist, dst, cand, grid=grid, interpret=interpret)
        add_mask = active & (new_dist[dst] == cur + 1)
        adds = jnp.where(add_mask, numsp[src], jnp.float32(0.0))
        new_numsp = sadd(numsp, dst, adds, grid=grid, interpret=interpret)
        changed = _changed_any((new_dist != dist) | (new_numsp != numsp))
        return new_dist, new_numsp, changed

    return bc_fwd_step


def make_bc_bwd_step(interpret=True, grid=None, use_pallas=True):
    """BC backward superstep: delta from published ratios, scatter-added by
    *source* (each vertex sums its successors' ratios)."""
    sadd = k.edge_scatter_add if use_pallas else (
        lambda b, i, v, **_: k.edge_scatter_add_jnp(b, i, v)
    )

    def bc_bwd_step(dist, numsp, delta, bc, ratio, src, dst, si32):
        cur = si32[0]
        sums = sadd(jnp.zeros_like(ratio), src, ratio[dst], grid=grid, interpret=interpret)
        at = dist == cur
        new_delta = jnp.where(at, numsp * sums, delta)
        new_bc = bc + jnp.where(at, new_delta, jnp.float32(0.0))
        safe = jnp.maximum(numsp, jnp.float32(1e-30))
        new_ratio = jnp.where(at & (numsp > 0), (1.0 + new_delta) / safe, jnp.float32(0.0))
        return dist, numsp, new_delta, new_bc, new_ratio, jnp.ones((1,), jnp.int32)

    return bc_bwd_step


# --- registry: the contract aot.py lowers and rust validates ---------------

PROGRAMS = {
    "bfs": dict(
        make=make_bfs_step,
        arrays=["i32"],
        aux=[],
        weights=False,
        si32=1,
        sf32=0,
        orientation="fwd",
    ),
    "sssp": dict(
        make=make_sssp_step,
        arrays=["f32"],
        aux=[],
        weights=True,
        si32=0,
        sf32=0,
        orientation="fwd",
    ),
    "widest": dict(
        make=make_widest_step,
        arrays=["f32"],
        aux=[],
        weights=True,
        si32=0,
        sf32=0,
        orientation="fwd",
    ),
    "cc": dict(
        make=make_cc_step,
        arrays=["i32"],
        aux=[],
        weights=False,
        si32=0,
        sf32=0,
        orientation="fwd",
    ),
    "pagerank": dict(
        make=make_pagerank_step,
        arrays=["f32", "f32"],
        aux=["f32", "f32"],
        weights=False,
        si32=0,
        sf32=2,
        orientation="rev",
    ),
    "bc_fwd": dict(
        make=make_bc_fwd_step,
        arrays=["i32", "f32"],
        aux=[],
        weights=False,
        si32=1,
        sf32=0,
        orientation="fwd",
    ),
    "bc_bwd": dict(
        make=make_bc_bwd_step,
        arrays=["i32", "f32", "f32", "f32", "f32"],
        aux=[],
        weights=False,
        si32=1,
        sf32=0,
        orientation="fwd",
    ),
}
