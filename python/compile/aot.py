"""AOT lowering: JAX/Pallas superstep functions -> HLO text artifacts.

Runs ONCE at build time (``make artifacts``); the Rust runtime loads the
HLO text through ``HloModuleProto::from_text_file`` and compiles it on the
PJRT CPU client. Python is never on the request path.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Each program in ``model.PROGRAMS`` is lowered at every size class
``(n_cap, e_cap)``; ``manifest.json`` records the marshaling contract the
Rust side validates (``rust/src/runtime/manifest.rs``).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import PROGRAMS

# (n_cap, e_cap) size classes. n_cap-1 is the dummy sink; a partition needs
# state_len < n_cap and edges <= e_cap. The ladder covers RMAT12..RMAT20
# offload fractions (DESIGN.md §3).
SIZE_CLASSES = [
    (1 << 12, 1 << 15),
    (1 << 13, 1 << 16),
    (1 << 14, 1 << 17),
    (1 << 15, 1 << 18),
    (1 << 16, 1 << 19),
    (1 << 17, 1 << 20),
    (1 << 18, 1 << 21),
    (1 << 19, 1 << 22),
    (1 << 20, 1 << 23),
]

_DTYPES = {"i32": jnp.int32, "f32": jnp.float32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_args(meta, n_cap: int, e_cap: int):
    """ShapeDtypeStructs in the positional marshaling contract order."""
    args = []
    for dt in meta["arrays"]:
        args.append(jax.ShapeDtypeStruct((n_cap,), _DTYPES[dt]))
    for dt in meta["aux"]:
        args.append(jax.ShapeDtypeStruct((n_cap,), _DTYPES[dt]))
    args.append(jax.ShapeDtypeStruct((e_cap,), jnp.int32))  # src
    args.append(jax.ShapeDtypeStruct((e_cap,), jnp.int32))  # dst
    if meta["weights"]:
        args.append(jax.ShapeDtypeStruct((e_cap,), jnp.float32))
    if meta["si32"]:
        args.append(jax.ShapeDtypeStruct((meta["si32"],), jnp.int32))
    if meta["sf32"]:
        args.append(jax.ShapeDtypeStruct((meta["sf32"],), jnp.float32))
    return args


def lower_one(name: str, meta, n_cap: int, e_cap: int, out_dir: str,
              use_pallas: bool = True, force: bool = False):
    fname = f"{name}_n{n_cap}_e{e_cap}.hlo.txt"
    path = os.path.join(out_dir, fname)
    entry = {
        "name": name,
        "n_cap": n_cap,
        "e_cap": e_cap,
        "file": fname,
        "arrays": meta["arrays"],
        "aux": meta["aux"],
        "weights": meta["weights"],
        "si32": meta["si32"],
        "sf32": meta["sf32"],
        "orientation": meta["orientation"],
    }
    if not force and os.path.exists(path):
        return entry, False
    step = meta["make"](interpret=True, use_pallas=use_pallas)
    lowered = jax.jit(step).lower(*example_args(meta, n_cap, e_cap))
    text = to_hlo_text(lowered)
    with open(path + ".tmp", "w") as f:
        f.write(text)
    os.replace(path + ".tmp", path)
    return entry, True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated program names (default: all)")
    ap.add_argument("--classes", default=None,
                    help="comma-separated class indices (default: all)")
    ap.add_argument("--force", action="store_true", help="re-lower even if present")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = args.only.split(",") if args.only else list(PROGRAMS)
    class_idx = (
        [int(i) for i in args.classes.split(",")]
        if args.classes
        else range(len(SIZE_CLASSES))
    )

    entries = []
    fresh = 0
    for name in names:
        meta = PROGRAMS[name]
        for ci in class_idx:
            n_cap, e_cap = SIZE_CLASSES[ci]
            entry, built = lower_one(name, meta, n_cap, e_cap, args.out, force=args.force)
            entries.append(entry)
            fresh += built
            print(f"[aot] {entry['file']}{' (cached)' if not built else ''}", flush=True)

    # ablation variant: the pure-jnp lowering of BFS at the mid classes,
    # used by `cargo bench ablation` to compare pallas vs plain-XLA codegen.
    meta = dict(PROGRAMS["bfs"])
    for ci in (2, 3, 4):
        n_cap, e_cap = SIZE_CLASSES[ci]
        jnp_entry, built = lower_one(
            "bfs_jnp", {**meta, "make": lambda **kw: PROGRAMS["bfs"]["make"](
                **{**kw, "use_pallas": False})},
            n_cap, e_cap, args.out, force=args.force,
        )
        entries.append(jnp_entry)
        fresh += built
        print(f"[aot] {jnp_entry['file']}{' (cached)' if not built else ''}", flush=True)

    manifest = {"version": 1, "programs": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(entries)} entries ({fresh} lowered fresh)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
