//! Web-page ranking (paper §7.1): PageRank on the UK-WEB proxy crawl.
//!
//! Reproduces the §7.1 experiment shape: compares HIGH / LOW / RAND
//! partitioning for PageRank on a web-like scale-free graph, showing
//! (i) LOW lets the accelerator hold more edges for state-heavy
//! algorithms, (ii) HIGH minimizes the CPU's per-vertex write work, and
//! prints the top-ranked pages.
//!
//! Run:  `cargo run --release --example webrank -- [--scale N] [--alpha F]`

use totem::engine::EngineConfig;
use totem::graph::{RmatParams, Workload};
use totem::harness::{measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, fmt_teps, Table};
use totem::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let alpha = args.f64_or("alpha", 0.7).map_err(anyhow::Error::msg)?;
    let rounds = args.usize_or("rounds", 5).map_err(anyhow::Error::msg)?;

    // web-like graph: heavier skew than the social proxy
    let g = match args.get("scale") {
        Some(s) => {
            let scale: u32 = s.parse().map_err(|e| anyhow::anyhow!("--scale: {e}"))?;
            totem::graph::CsrGraph::from_edge_list(&totem::graph::rmat(&RmatParams {
                scale,
                avg_degree: 35,
                a: 0.62,
                b: 0.19,
                c: 0.17,
                permute: true,
                seed: 42,
            }))
        }
        None => Workload::UkWebProxy.build(42),
    };
    println!(
        "== PageRank on UK-WEB proxy: |V| = {}, |E| = {} links, {rounds} rounds ==",
        g.vertex_count,
        g.edge_count()
    );

    let host = measure(
        &g,
        RunSpec::new(AlgKind::Pagerank).with_rounds(rounds),
        &EngineConfig::host_only(1),
        2,
    )?;
    println!(
        "host-only: {} ({})",
        fmt_secs(host.makespan_secs),
        fmt_teps(host.teps)
    );

    let mut table = Table::new(
        "Partitioning strategies (paper Fig. 15/16 shape)",
        &["strategy", "CPU verts", "accel verts", "makespan", "rate", "speedup", "comm"],
    );
    let mut ranks: Option<Vec<f32>> = None;
    for strategy in [Strategy::Rand, Strategy::High, Strategy::Low] {
        let cfg = EngineConfig::hybrid(1, alpha, strategy).with_artifacts("artifacts");
        match measure(&g, RunSpec::new(AlgKind::Pagerank).with_rounds(rounds), &cfg, 2) {
            Ok(m) => {
                table.row(vec![
                    strategy.name().into(),
                    m.last.vertices[0].to_string(),
                    m.last.vertices[1].to_string(),
                    fmt_secs(m.makespan_secs),
                    fmt_teps(m.teps),
                    format!("{:.2}x", host.makespan_secs / m.makespan_secs),
                    fmt_secs(m.comm_secs),
                ]);
                ranks = Some(m.last.output.as_f32().to_vec());
            }
            Err(e) => {
                // paper Fig 15: "missing bars represent cases where the
                // GPU's memory space is not enough"
                table.row(vec![
                    strategy.name().into(),
                    "-".into(),
                    "-".into(),
                    format!("does not fit ({e})"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    print!("{}", table.markdown());

    if let Some(r) = ranks {
        let mut idx: Vec<usize> = (0..r.len()).collect();
        idx.sort_by(|&a, &b| r[b].partial_cmp(&r[a]).unwrap());
        println!("\ntop 5 pages by rank:");
        for &v in idx.iter().take(5) {
            println!("  page {v:>8}  rank {:.6}  in-degree-driven", r[v]);
        }
    }
    Ok(())
}
