//! Finding the main actors in a social network (paper §7.2):
//! Betweenness Centrality on the Twitter-proxy follower graph, plus a
//! point-to-point shortest-path query (§7.3) on the same network.
//!
//! Run:  `cargo run --release --example social_influencers -- [--scale N]`

use totem::engine::EngineConfig;
use totem::graph::generator::{rmat, with_random_weights, RmatParams};
use totem::graph::CsrGraph;
use totem::harness::{measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, fmt_teps, Table};
use totem::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let scale = args.usize_or("scale", 14).map_err(anyhow::Error::msg)? as u32;
    let alpha = args.f64_or("alpha", 0.7).map_err(anyhow::Error::msg)?;

    // Twitter-like follower network (skewed, degree 36)
    let mut el = rmat(&RmatParams {
        scale,
        avg_degree: 36,
        a: 0.60,
        b: 0.19,
        c: 0.19,
        permute: true,
        seed: 7,
    });
    with_random_weights(&mut el, 64, 8); // "common-follower distance" weights
    let g = CsrGraph::from_edge_list(&el);
    println!(
        "== social network: |V| = {} users, |E| = {} follow links ==",
        g.vertex_count,
        g.edge_count()
    );

    // ---- Betweenness Centrality: who brokers information flow? ----------
    let mut table = Table::new(
        "BC: hybrid vs host (paper Fig. 19 shape)",
        &["config", "makespan", "rate", "speedup"],
    );
    let host = measure(&g, RunSpec::new(AlgKind::Bc).with_source(1), &EngineConfig::host_only(1), 2)?;
    table.row(vec![
        "2S host".into(),
        fmt_secs(host.makespan_secs),
        fmt_teps(host.teps),
        "1.00x".into(),
    ]);
    let mut bc_scores: Vec<f32> = host.last.output.as_f32().to_vec();
    for strategy in [Strategy::High, Strategy::Low] {
        let cfg = EngineConfig::hybrid(1, alpha, strategy).with_artifacts("artifacts");
        let m = measure(&g, RunSpec::new(AlgKind::Bc).with_source(1), &cfg, 2)?;
        table.row(vec![
            format!("2S1G {}", strategy.name()),
            fmt_secs(m.makespan_secs),
            fmt_teps(m.teps),
            format!("{:.2}x", host.makespan_secs / m.makespan_secs),
        ]);
        bc_scores = m.last.output.as_f32().to_vec();
    }
    print!("{}", table.markdown());

    let mut idx: Vec<usize> = (0..bc_scores.len()).collect();
    idx.sort_by(|&a, &b| bc_scores[b].partial_cmp(&bc_scores[a]).unwrap());
    println!("\ntop 5 information brokers (betweenness):");
    for &v in idx.iter().take(5) {
        println!("  user {v:>8}  score {:.1}", bc_scores[v]);
    }

    // ---- point-to-point shortest path (§7.3) ------------------------------
    let cfg = EngineConfig::hybrid(1, alpha, Strategy::High).with_artifacts("artifacts");
    let m = measure(&g, RunSpec::new(AlgKind::Sssp).with_source(1), &cfg, 2)?;
    let dist = m.last.output.as_f32();
    let reachable = dist.iter().filter(|d| d.is_finite()).count();
    let target = idx[0] as usize;
    println!(
        "\nSSSP from user 1 (hybrid, HIGH): {} in {} — {} users reachable",
        fmt_teps(m.teps),
        fmt_secs(m.makespan_secs),
        reachable
    );
    if dist[target].is_finite() {
        println!(
            "  shortest weighted path from user 1 to top broker {target}: {:.1}",
            dist[target]
        );
    }
    Ok(())
}
