//! Quickstart: the end-to-end driver proving all layers compose.
//!
//! Generates a scale-free RMAT graph (Graph500 parameters), runs BFS
//! host-only and then on the hybrid platform (CPU partition + accelerator
//! partition executing the AOT JAX/Pallas program through PJRT), verifies
//! the hybrid result against the sequential baseline, and reports the
//! paper's headline metric (traversal rate in TEPS) plus the speedup and
//! communication statistics.
//!
//! Run:  `make artifacts && cargo run --release --example quickstart`
//! Flags: `--scale N` (default 13), `--alpha F` (default 0.75),
//!        `--strategy rand|high|low` (default high)

use totem::baseline;
use totem::engine::{self, EngineConfig};
use totem::graph::Workload;
use totem::harness::{measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_secs, fmt_teps};
use totem::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let scale = args.usize_or("scale", 13).map_err(anyhow::Error::msg)? as u32;
    let alpha = args.f64_or("alpha", 0.75).map_err(anyhow::Error::msg)?;
    let strategy =
        Strategy::parse(&args.str_or("strategy", "high")).map_err(anyhow::Error::msg)?;

    println!("== TOTEM quickstart: BFS on RMAT{scale} ==");
    let g = Workload::Rmat(scale).build(42);
    println!(
        "graph: |V| = {}, |E| = {} (scale-free, avg degree 16)",
        g.vertex_count,
        g.edge_count()
    );

    // 1. host-only reference (the paper's 2S baseline)
    let host = measure(&g, RunSpec::new(AlgKind::Bfs), &EngineConfig::host_only(1), 3)?;
    println!(
        "\n[host-only]  makespan {}   rate {}",
        fmt_secs(host.makespan_secs),
        fmt_teps(host.teps)
    );

    // 2. hybrid: CPU keeps `alpha` of the edges, accelerator takes the rest
    let cfg = EngineConfig::hybrid(1, alpha, strategy).with_artifacts("artifacts");
    let hyb = measure(&g, RunSpec::new(AlgKind::Bfs), &cfg, 3)?;
    let r = &hyb.last;
    println!(
        "[hybrid 1G]  makespan {}   rate {}   ({} partitioning, α = {:.0}%)",
        fmt_secs(hyb.makespan_secs),
        fmt_teps(hyb.teps),
        strategy.name(),
        100.0 * alpha
    );
    println!(
        "             CPU partition: {} vertices / {} edges; accel: {} vertices / {} edges",
        r.footprints[0].vertices,
        r.footprints[0].edges,
        r.footprints[1].vertices,
        r.footprints[1].edges
    );
    println!(
        "             β: {:.1}% boundary edges → {:.1}% messages after reduction",
        100.0 * r.beta.beta_raw(),
        100.0 * r.beta.beta_reduced()
    );
    println!(
        "             compute: CPU {} | accel {};  communication {}",
        fmt_secs(r.metrics.partition_compute_secs(0)),
        fmt_secs(r.metrics.partition_compute_secs(1)),
        fmt_secs(hyb.comm_secs)
    );
    println!(
        "\nspeedup vs host-only (concurrent-makespan accounting): {:.2}x",
        host.makespan_secs / hyb.makespan_secs
    );

    // 3. verify against the sequential oracle
    let expect = baseline::bfs(&g, 0);
    let mut alg = totem::alg::bfs::Bfs::new(0);
    let check = engine::run(&g, &mut alg, &cfg)?;
    assert_eq!(check.output.as_i32(), expect.as_slice(), "hybrid output mismatch!");
    let visited = expect.iter().filter(|&&l| l != totem::alg::INF_I32).count();
    println!("verified: hybrid levels == sequential BFS ({visited} vertices reached)");
    Ok(())
}
