//! Scalability sweep (paper §8, Figure 23 shape): BFS and PageRank across
//! graph sizes and hardware configurations (xSyG), reporting TEPS.
//!
//! `1S` vs `2S` differ only in CPU worker threads (one core on this
//! container — the structure is exercised, the speedup is not observable;
//! see DESIGN.md §2). The hybrid columns exercise the real accelerator
//! element.
//!
//! Run: `cargo run --release --example scalability_sweep -- [--scales 11,12,13]`

use totem::engine::EngineConfig;
use totem::graph::Workload;
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::partition::Strategy;
use totem::report::{fmt_teps, Table};
use totem::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let scales = args
        .f64_list_or("scales", &[11.0, 12.0, 13.0])
        .map_err(anyhow::Error::msg)?;
    let alpha = args.f64_or("alpha", 0.7).map_err(anyhow::Error::msg)?;

    for alg in [AlgKind::Bfs, AlgKind::Pagerank] {
        let mut table = Table::new(
            &format!("{} traversal rate by config (Fig. 23 shape)", alg.name()),
            &["workload", "1S", "2S", "1S1G", "2S1G", "2S2G"],
        );
        for &s in &scales {
            let scale = s as u32;
            let g = build_workload(Workload::Rmat(scale), 42, alg);
            let mut row = vec![format!("RMAT{scale}")];
            for hw in ["1S", "2S", "1S1G", "2S1G", "2S2G"] {
                let cfg = EngineConfig::from_notation(hw, alpha, Strategy::High, 1)
                    .map_err(anyhow::Error::msg)?
                    .with_artifacts("artifacts");
                match measure(&g, RunSpec::new(alg), &cfg, 2) {
                    Ok(m) => row.push(fmt_teps(m.teps)),
                    Err(_) => row.push("-".into()), // does not fit the accelerator
                }
            }
            table.row(row);
        }
        print!("{}", table.markdown());
        println!();
    }
    Ok(())
}
